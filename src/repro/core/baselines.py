"""Baselines the paper compares against: PRANC, NOLA, (plain LoRA lives in
core/adapters.py; pruning accounting lives in benchmarks/table1_vit.py).

PRANC (Nooralinejad et al. 2023): theta = theta0 + sum_i c_i v_i with frozen
random basis vectors — exactly MCNC with a *linear depth-1 generator* (the
paper: "when no activation is used, our method recovers a variation of
PRANC"). We therefore express PRANC as a GeneratorConfig and reuse the entire
chunking/expansion/optimizer stack.

NOLA (Koohpayegani et al. 2024): LoRA factors expressed as learned linear
combinations of frozen random bases: A = sum_i c^A_i A_i, B = sum_j c^B_j B_j.
Reconstruction FLOPs per m x r factor = 2 * n_bases * m * r (paper A.6).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.generator import GeneratorConfig
from repro.core.reparam import flatten_with_paths, unflatten_paths
from repro.core.adapters import LORA_A_SUFFIX, LORA_B_SUFFIX

Array = jax.Array
PyTree = Any


def pranc_generator(k: int, d: int, seed: int = 0) -> GeneratorConfig:
    """PRANC = linear generator: one frozen random k x d matrix per chunk
    space. freq=1, no activation, depth=1."""
    return GeneratorConfig(k=k, d=d, width=0, depth=1, freq=1.0,
                           activation="none", seed=seed)


# ---------------------------------------------------------------------------
# NOLA
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NolaConfig:
    n_bases: int = 64
    seed: int = 7
    dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class NolaPlan:
    cfg: NolaConfig
    # path -> flattened leaf size
    leaves: dict[str, tuple[tuple[int, ...], int]]

    @property
    def trainable_params(self) -> int:
        return self.cfg.n_bases * len(self.leaves)

    def reconstruction_flops(self) -> int:
        return sum(2 * self.cfg.n_bases * numel
                   for _, (_, numel) in sorted(self.leaves.items()))


def plan_nola(adapter_specs: PyTree, cfg: NolaConfig) -> NolaPlan:
    """One coefficient vector per adapter factor leaf (A and B separately,
    as in the NOLA paper)."""
    flat = flatten_with_paths(adapter_specs)
    leaves = {}
    for path, leaf in flat.items():
        if LORA_A_SUFFIX not in path and LORA_B_SUFFIX not in path:
            continue
        shape = tuple(int(s) for s in leaf.shape)
        leaves[path] = (shape, int(np.prod(shape)))
    return NolaPlan(cfg=cfg, leaves=leaves)


def _leaf_key(seed: int, path: str) -> jax.Array:
    # Stable per-leaf key derived from the seed and the path hash.
    h = np.uint32(abs(hash(path)) % (2 ** 31))
    return jax.random.fold_in(jax.random.PRNGKey(seed), h)


def nola_basis(plan: NolaPlan, path: str) -> Array:
    """Frozen random basis (n_bases, numel) for one leaf, ~N(0, 1/n_bases)."""
    shape, numel = plan.leaves[path]
    key = _leaf_key(plan.cfg.seed, path)
    return jax.random.normal(key, (plan.cfg.n_bases, numel),
                             jnp.dtype(plan.cfg.dtype)) / np.sqrt(plan.cfg.n_bases)


def init_nola_state(plan: NolaPlan) -> PyTree:
    """Coefficients: random for A-factors, zero for B-factors => product is
    exactly zero at init (mirrors LoRA's A-random/B-zero)."""
    flat = {}
    for path in sorted(plan.leaves):
        key = _leaf_key(plan.cfg.seed + 1, path)
        if LORA_B_SUFFIX in path:
            flat[path] = jnp.zeros((plan.cfg.n_bases,), jnp.dtype(plan.cfg.dtype))
        else:
            flat[path] = jax.random.normal(key, (plan.cfg.n_bases,),
                                           jnp.dtype(plan.cfg.dtype))
    return unflatten_paths(flat)


def expand_nola(plan: NolaPlan, state: PyTree) -> PyTree:
    """coeffs -> adapter leaves (replaces the adapter values entirely)."""
    flat_state = flatten_with_paths(state)
    out = {}
    for path, (shape, _numel) in plan.leaves.items():
        basis = nola_basis(plan, path)
        coeff = flat_state[path]
        out[path] = (coeff @ basis).reshape(shape)
    return unflatten_paths(out)
