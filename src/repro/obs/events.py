"""Per-request lifecycle event log for the serving engine.

Every request's life is recorded as an ordered sequence of named events with
monotonic timestamps (``time.perf_counter``):

    submit -> queued -> admitted -> prefill | prefill_chunk[i]*
           -> decode_block[j]* -> [deadline_miss]
           -> finish | evict | cancel | failed
    submit -> reject
    submit -> [retry] -> queued -> ...   (a resubmission after a retryable
                                          failure, under a FRESH req_id)

``submit`` is the engine API boundary, ``queued`` the scheduler accepting the
request into its admission queue, ``admitted`` the step it wins a KV slot
(and, paged, its lifetime page reservation). Whole prompts cache in one
``prefill`` event; long prompts under chunked prefill record one
``prefill_chunk`` per piece (the last one emits the first token). Each fused
decode block a request harvests tokens from records one ``decode_block``
event carrying the token count. Exactly one terminal event ends the
sequence: ``finish`` (budget emitted), ``evict`` (reserved for preemption —
no engine path emits it yet), ``cancel`` (client abort, any point after
queued), ``failed`` (the request's fault domain collapsed — a corrupt
bundle, an expansion error, allocator exhaustion, or a quarantined
non-finite decode block; the event's ``cause`` datum names the fault and
``retryable`` says whether the frontend may resubmit), or ``reject``
(load-shedding admission refused the request — it never entered the
scheduler, so ``submit`` is the only event before it). ``retry`` marks a
resubmission attempt after a retryable failure: it is emitted under the NEW
attempt's req_id (failed/reject are terminal, so nothing may follow on the
old id) carrying ``prev_req_id``/``attempt``/``backoff_s``, sits at the
queued rank, and may repeat (each attempt of a multi-retry lifecycle logs
its own).
``deadline_miss`` is informational, not terminal: it marks the moment the
request was known to have blown its deadline (stamped just before the
terminal event that resolves it) so SLO dashboards can count misses without
re-deriving deadlines from request metadata.

From this log the engine derives the latency numbers the ROADMAP's SLO work
needs per request — TTFT, queue wait, inter-token latency, end-to-end — and
feeds them into the existing ``Metrics`` histograms (`summary`). The log is
the authoritative source: the derived values and the raw events always agree
because they share the same timestamps.

No jax imports; appends are O(1) dict/list work, cheap enough to stay on in
production (the *span tracer* is the opt-in part of the observability layer).
Memory is bounded: finished requests beyond ``max_finished`` are dropped
oldest-first.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable

SUBMIT = "submit"
QUEUED = "queued"
ADMITTED = "admitted"
PREFILL = "prefill"
PREFILL_CHUNK = "prefill_chunk"
DECODE_BLOCK = "decode_block"
FINISH = "finish"
EVICT = "evict"
CANCEL = "cancel"
FAILED = "failed"
DEADLINE_MISS = "deadline_miss"
REJECT = "reject"
RETRY = "retry"

# rank of each event name in a request's life; events must be emitted in
# non-decreasing rank (the repeatable ones share their rank).  cancel,
# failed, and reject share the terminal rank; deadline_miss sits at the
# decode rank so it can legally follow any amount of progress (including
# none — a request shed while still queued jumps straight from rank 1 to
# rank 4) and still precede the terminal event.  retry sits at the queued
# rank: a resubmission is logged under the new attempt's req_id right after
# its submit, before (or alongside) its queued event.
LIFECYCLE_ORDER = {SUBMIT: 0, QUEUED: 1, RETRY: 1, ADMITTED: 2, PREFILL: 3,
                   PREFILL_CHUNK: 3, DECODE_BLOCK: 4, DEADLINE_MISS: 4,
                   FINISH: 5, EVICT: 5, CANCEL: 5, FAILED: 5, REJECT: 5}

# events that may legally repeat within one request
REPEATABLE_EVENTS = frozenset({PREFILL_CHUNK, DECODE_BLOCK, RETRY})

TERMINAL_EVENTS = frozenset({FINISH, EVICT, CANCEL, FAILED, REJECT})

# events that deliver generated tokens to the request (their `tokens` datum
# feeds the inter-token-latency derivation)
TOKEN_EVENTS = frozenset({PREFILL, PREFILL_CHUNK, DECODE_BLOCK})


@dataclasses.dataclass(frozen=True)
class Event:
    """One lifecycle record: request id, event name, monotonic seconds, and
    free-form integer/float annotations (token counts, chunk offsets)."""
    req_id: int
    name: str
    t: float
    data: dict


class EventLog:
    """Append-only per-request lifecycle log with derived latency summaries.

    clock: monotonic seconds source (``time.perf_counter``; injectable so
    tests can drive deterministic timelines).
    max_finished: finished/evicted request logs retained before the oldest
    are dropped (live requests are never dropped).
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 max_finished: int = 10_000):
        self._clock = clock
        self.max_finished = max_finished
        self._events: OrderedDict[int, list[Event]] = OrderedDict()
        self._finished: list[int] = []      # FIFO of terminal req_ids

    # ------------------------------------------------------------------
    def emit(self, req_id: int, name: str, **data) -> Event:
        """Record one event for a request at the current clock reading.
        Returns the Event (tests and the engine read its timestamp back)."""
        ev = Event(req_id=int(req_id), name=name, t=self._clock(), data=data)
        self._events.setdefault(ev.req_id, []).append(ev)
        if name in TERMINAL_EVENTS:
            self._finished.append(ev.req_id)
            while len(self._finished) > self.max_finished:
                self._events.pop(self._finished.pop(0), None)
        return ev

    def clear(self):
        """Drop every retained event (warmup hygiene: benchmarks replay
        traffic to compile shapes, then clear so the measured window's
        lifecycles — and req-id space — start clean)."""
        self._events.clear()
        self._finished.clear()

    def request_ids(self) -> list[int]:
        """Request ids with retained events, oldest first."""
        return list(self._events)

    def events_for(self, req_id: int) -> list[Event]:
        """The request's events in emission order (empty if dropped)."""
        return list(self._events.get(req_id, ()))

    def __len__(self) -> int:
        return sum(len(v) for v in self._events.values())

    # ------------------------------------------------------------------
    def validate(self, req_id: int) -> list[str]:
        """Ordering-invariant check for one request; returns violation
        strings (empty = valid). Invariants: timestamps non-decreasing,
        lifecycle ranks non-decreasing, non-repeatable events unique, at
        most one terminal event and nothing after it, terminal sequences
        contain exactly one terminal event."""
        evs = self.events_for(req_id)
        out: list[str] = []
        if not evs:
            return [f"req {req_id}: no events"]
        seen: dict[str, int] = {}
        last_t, last_rank, terminal = -float("inf"), -1, None
        for ev in evs:
            if ev.name not in LIFECYCLE_ORDER:
                out.append(f"req {req_id}: unknown event {ev.name!r}")
                continue
            if terminal is not None:
                out.append(f"req {req_id}: {ev.name!r} after terminal "
                           f"{terminal!r}")
            if ev.t < last_t:
                out.append(f"req {req_id}: timestamp went backwards at "
                           f"{ev.name!r} ({ev.t} < {last_t})")
            rank = LIFECYCLE_ORDER[ev.name]
            if rank < last_rank:
                out.append(f"req {req_id}: {ev.name!r} out of lifecycle "
                           "order")
            if ev.name in seen and ev.name not in REPEATABLE_EVENTS:
                out.append(f"req {req_id}: duplicate {ev.name!r}")
            seen[ev.name] = seen.get(ev.name, 0) + 1
            last_t, last_rank = ev.t, rank
            if ev.name in TERMINAL_EVENTS:
                terminal = ev.name
        n_term = sum(seen.get(t, 0) for t in TERMINAL_EVENTS)
        if n_term > 1:
            out.append(f"req {req_id}: {n_term} terminal events")
        return out

    def validate_all(self, *, require_terminal: bool = False) -> list[str]:
        """validate() across every retained request; with require_terminal,
        additionally flag requests that never reached a terminal event
        (drained-engine invariant)."""
        out: list[str] = []
        for rid in self._events:
            out.extend(self.validate(rid))
            if require_terminal and not any(
                    e.name in TERMINAL_EVENTS for e in self._events[rid]):
                out.append(f"req {rid}: no terminal event")
        return out

    # ------------------------------------------------------------------
    def summary(self, req_id: int) -> dict:
        """Derived per-request latency numbers from the raw events.

        Returns a dict with (seconds, None when underivable):
          queue_wait_s   submit -> admitted
          ttft_s         submit -> first token (first token-bearing event
                         that actually delivered tokens)
          e2e_s          submit -> terminal event
          itl_samples    per-token inter-token latencies: for each token
                         delivery AFTER the first token, the wall time since
                         the previous delivery divided by the tokens it
                         brought (fused blocks amortize one sync over K
                         tokens — that is the latency a streaming client
                         would observe per token at block granularity)
          n_tokens       generated tokens delivered across token events
          terminal       name of the terminal event (None while live)
          deadline_missed  True iff a deadline_miss event was recorded
          failed         True iff the terminal event is ``failed``
          retries        count of retry events recorded under this req_id

        Degenerate lifecycles stay well-defined: a request that finishes
        during prefill (``max_new_tokens == 1``) gets its TTFT from the
        token-bearing prefill event and an empty itl_samples; a request
        cancelled or evicted with 0 or 1 delivered tokens yields
        ``ttft_s is None`` (0 tokens) or an empty itl list (1 token) —
        never a division by zero — and e2e_s derives from whichever
        terminal event ended it, cancel and reject included.
        """
        evs = self.events_for(req_id)
        t_submit = next((e.t for e in evs if e.name == SUBMIT), None)
        t_admit = next((e.t for e in evs if e.name == ADMITTED), None)
        term = next((e for e in evs if e.name in TERMINAL_EVENTS), None)
        t_term = None if term is None else term.t
        t_first = None
        itl: list[float] = []
        n_tokens = 0
        t_prev = None
        for ev in evs:
            if ev.name not in TOKEN_EVENTS:
                continue
            tok = int(ev.data.get("tokens", 0))
            if tok <= 0:            # mid-prompt chunk: no tokens delivered
                continue
            n_tokens += tok
            if t_first is None:
                t_first = ev.t      # first delivery: no prior sync to
            else:                   # measure an inter-token gap against
                itl.extend([(ev.t - t_prev) / tok] * tok)
            t_prev = ev.t
        delta = (lambda a, b: None if a is None or b is None else b - a)
        return {
            "queue_wait_s": delta(t_submit, t_admit),
            "ttft_s": delta(t_submit, t_first),
            "e2e_s": delta(t_submit, t_term),
            "itl_samples": itl,
            "n_tokens": n_tokens,
            "terminal": None if term is None else term.name,
            "deadline_missed": any(e.name == DEADLINE_MISS for e in evs),
            "failed": term is not None and term.name == FAILED,
            "retries": sum(1 for e in evs if e.name == RETRY),
        }
