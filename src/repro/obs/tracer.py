"""Span tracer emitting Chrome trace-event JSON (Perfetto-loadable).

The serving engine wraps its phases in spans — MCNC expansion, adapter
stacking, page alloc/free, prefill groups/chunks, and every fused decode
block — annotated with the numbers that explain a stall (batch size, horizon
K, live pages, jit-compile counts). ``to_chrome()`` renders the standard
trace-event format: open the JSON at https://ui.perfetto.dev (or
chrome://tracing) and the serving timeline lays out on one track per
subsystem; docs/OBSERVABILITY.md walks through it.

Tracing is strictly opt-in and zero-cost when off: the engine holds
``NULL_TRACER`` by default, whose ``span``/``instant``/``counter`` are
no-ops returning a shared reusable null context (no allocation on the hot
path). benchmarks/serve_bench.py hard-gates the enabled-tracing overhead on
decode throughput.

Event fields follow the trace-event spec: ``ph`` "X" complete spans with
microsecond ``ts``/``dur``, ``ph`` "i" instants, ``ph`` "C" counter series,
``ph`` "M" metadata naming the process and the per-subsystem thread lanes.
No jax imports; timestamps come from the injectable monotonic clock.
"""
from __future__ import annotations

import json
import time
from typing import Callable

# logical thread lanes: one Perfetto track per serving subsystem
TID_ENGINE = 0      # scheduler steps, request lifecycle instants
TID_PREFILL = 1     # prefill groups + chunks
TID_DECODE = 2      # fused decode blocks
TID_EXPAND = 3      # MCNC expansion + adapter stacking
TID_PAGES = 4       # page allocation / free

THREAD_NAMES = {TID_ENGINE: "engine", TID_PREFILL: "prefill",
                TID_DECODE: "decode", TID_EXPAND: "expand/adapters",
                TID_PAGES: "kv-pages"}


class _Span:
    """Context manager for one in-flight span; records a ph-"X" complete
    event (start + duration) when exited."""
    __slots__ = ("_tracer", "_name", "_tid", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, tid: int, args: dict):
        self._tracer = tracer
        self._name = name
        self._tid = tid
        self._args = args

    def __enter__(self):
        self._t0 = self._tracer._clock()
        return self

    def note(self, **args):
        """Attach args discovered while the span body runs (e.g. how many
        pages an alloc span actually allocated)."""
        self._args.update(args)

    def __exit__(self, *exc):
        tr = self._tracer
        t1 = tr._clock()
        tr.events.append({
            "name": self._name, "ph": "X", "pid": tr.pid, "tid": self._tid,
            "ts": tr._us(self._t0), "dur": max(0.0, (t1 - self._t0) * 1e6),
            "cat": "serve", "args": self._args})
        return False


class _NullSpan:
    """Reusable no-op context manager (the disabled tracer's span)."""
    __slots__ = ()

    def __enter__(self):
        """No-op enter."""
        return self

    def note(self, **args):
        """No-op note."""

    def __exit__(self, *exc):
        """No-op exit."""
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Recording span tracer. ``enabled`` is True; the engine branches on it
    only where even a no-op call would be per-token work.

    clock: monotonic seconds source (injectable for deterministic tests).
    pid: trace-event process id (one engine = one process row).
    """

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 pid: int = 1):
        self._clock = clock
        self.pid = pid
        self._t0 = clock()
        self.events: list[dict] = []

    def _us(self, t: float) -> float:
        """Monotonic seconds -> microseconds since tracer start."""
        return (t - self._t0) * 1e6

    # ------------------------------------------------------------------
    def span(self, name: str, tid: int = TID_ENGINE, **args) -> _Span:
        """Context manager recording a complete ("X") span around its body.
        kwargs become the span's ``args`` annotations (batch, k, pages...)."""
        return _Span(self, name, tid, args)

    def instant(self, name: str, tid: int = TID_ENGINE, **args):
        """Record a zero-duration instant ("i") event (scope: thread)."""
        self.events.append({
            "name": name, "ph": "i", "s": "t", "pid": self.pid, "tid": tid,
            "ts": self._us(self._clock()), "cat": "serve", "args": args})

    def counter(self, name: str, **series: float):
        """Record a counter ("C") sample; each kwarg is one series on the
        counter track (e.g. pages_in_use=12)."""
        self.events.append({
            "name": name, "ph": "C", "pid": self.pid, "tid": TID_ENGINE,
            "ts": self._us(self._clock()), "cat": "serve",
            "args": dict(series)})

    # ------------------------------------------------------------------
    def to_chrome(self, process_name: str = "serve-engine") -> dict:
        """Render the recorded events as a Chrome trace-event JSON object
        ({"traceEvents": [...]}) with process/thread metadata rows."""
        meta: list[dict] = [{
            "name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
            "args": {"name": process_name}}]
        for tid, tname in THREAD_NAMES.items():
            meta.append({"name": "thread_name", "ph": "M", "pid": self.pid,
                         "tid": tid, "args": {"name": tname}})
        return {"traceEvents": meta + self.events,
                "displayTimeUnit": "ms"}

    def save(self, path: str, process_name: str = "serve-engine"):
        """Write the Chrome trace JSON to `path` (open in Perfetto)."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(process_name), f)
            f.write("\n")


class _NullTracer:
    """Disabled tracer: same surface as Tracer, every method a no-op (span
    returns a shared context manager — no per-call allocation)."""

    enabled = False
    events: list = []

    def span(self, name: str, tid: int = TID_ENGINE, **args) -> _NullSpan:
        """No-op span."""
        return _NULL_SPAN

    def instant(self, name: str, tid: int = TID_ENGINE, **args):
        """No-op instant."""

    def counter(self, name: str, **series: float):
        """No-op counter sample."""


NULL_TRACER = _NullTracer()
