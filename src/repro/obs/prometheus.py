"""Prometheus text-exposition (version 0.0.4) renderer over serve Metrics.

Renders every instrument a ``Metrics`` registry holds as the plain-text
format a Prometheus scrape endpoint serves: counters as ``<name>_total``,
gauges verbatim, histograms as CUMULATIVE ``_bucket{le="..."}`` series plus
``_sum``/``_count`` — the full distribution, not just the p50/p95 digests
``snapshot()`` carries, so dashboards can do their own quantile math
(``histogram_quantile`` over the bucket series).

Dependency-free on purpose (the container has no prometheus client): the
format is a stable, line-oriented text protocol, and emitting it directly
keeps the serving stack import-light. tests/test_obs.py pins the output
against a golden file so the exposition can never drift silently.
"""
from __future__ import annotations

import math
import re

_NAME_OK = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*$")
_NAME_FIX = re.compile(r"[^a-zA-Z0-9_:]")


def _fmt(v: float) -> str:
    """Prometheus sample value: integers bare, floats repr'd, inf/nan in
    Prometheus spelling (+Inf / -Inf / NaN)."""
    if isinstance(v, float):
        if math.isinf(v):
            return "+Inf" if v > 0 else "-Inf"
        if math.isnan(v):
            return "NaN"
        if v == int(v) and abs(v) < 1e15:
            return str(int(v))
        return repr(v)
    return str(v)


def metric_name(name: str, namespace: str = "") -> str:
    """Sanitize an instrument name into a legal Prometheus metric name,
    optionally prefixed ``<namespace>_``."""
    full = f"{namespace}_{name}" if namespace else name
    if not _NAME_OK.match(full):
        full = _NAME_FIX.sub("_", full)
        if not _NAME_OK.match(full):        # leading digit etc.
            full = "_" + full
    return full


def render_prometheus(metrics, namespace: str = "repro_serve") -> str:
    """Render a ``serve.metrics.Metrics`` registry as Prometheus text
    exposition. Counters gain the conventional ``_total`` suffix; histogram
    buckets are cumulative with a closing ``le="+Inf"`` bucket equal to the
    observation count. Output is deterministic (instruments sorted by name)
    so it can be golden-file tested."""
    lines: list[str] = []
    for name, kind, inst in metrics.instruments():
        full = metric_name(name, namespace)
        if kind == "counter":
            if not full.endswith("_total"):
                full += "_total"
            lines.append(f"# TYPE {full} counter")
            lines.append(f"{full} {_fmt(inst.value)}")
        elif kind == "gauge":
            lines.append(f"# TYPE {full} gauge")
            lines.append(f"{full} {_fmt(inst.value)}")
        elif kind == "histogram":
            lines.append(f"# TYPE {full} histogram")
            for bound, cum in inst.cumulative_buckets():
                lines.append(
                    f'{full}_bucket{{le="{_fmt(float(bound))}"}} {cum}')
            lines.append(f'{full}_bucket{{le="+Inf"}} {inst.count}')
            lines.append(f"{full}_sum {_fmt(float(inst.sum))}")
            lines.append(f"{full}_count {inst.count}")
        else:       # pragma: no cover - Metrics only mints the three kinds
            raise ValueError(f"unknown instrument kind {kind!r}")
    return "\n".join(lines) + "\n"
