# Serving observability: per-request lifecycle event log (obs/events.py),
# span tracer emitting Chrome trace-event JSON for Perfetto (obs/tracer.py),
# and a Prometheus text-exposition renderer over serve.metrics.Metrics
# (obs/prometheus.py). Pure python, no jax imports — the engine threads
# these through the serving stack; docs/OBSERVABILITY.md is the spec.
from repro.obs.events import (ADMITTED, CANCEL, DEADLINE_MISS, DECODE_BLOCK,
                              EVICT, FAILED, FINISH, LIFECYCLE_ORDER,
                              PREFILL, PREFILL_CHUNK, QUEUED, REJECT, RETRY,
                              SUBMIT, TERMINAL_EVENTS, Event, EventLog)
from repro.obs.prometheus import render_prometheus
from repro.obs.tracer import (NULL_TRACER, TID_DECODE, TID_ENGINE,
                              TID_EXPAND, TID_PAGES, TID_PREFILL,
                              THREAD_NAMES, Tracer)

__all__ = [
    "ADMITTED", "CANCEL", "DEADLINE_MISS", "DECODE_BLOCK", "EVICT", "Event",
    "EventLog", "FAILED", "FINISH", "LIFECYCLE_ORDER", "NULL_TRACER",
    "PREFILL",
    "PREFILL_CHUNK", "QUEUED", "REJECT", "RETRY", "SUBMIT",
    "TERMINAL_EVENTS",
    "THREAD_NAMES", "TID_DECODE", "TID_ENGINE", "TID_EXPAND", "TID_PAGES",
    "TID_PREFILL", "Tracer", "render_prometheus",
]
