"""Bundle wire-format v2: per-tensor quantization + lossless entropy coding.

MCNC's storage claim is that a task ships as a seed plus a small coefficient
state. Format v1 (``write_artifact``) stores that state as raw float32 in an
uncompressed ``arrays.npz`` — no compression at all on the one artifact the
paper says should be small. This module supplies the v2 pipeline:

  1. **Quantize** (lossy, optional): per-tensor symmetric int8 (NOLA shows
     coefficient vectors tolerate aggressive quantization) or nf4-style
     4-bit block quantization, with scale planes stored as float16;
  2. **Byte-group** (lossless transform): multi-byte elements are split into
     per-byte planes (all exponent-carrying high bytes together, all
     mantissa low bytes together — the ZipNN observation that model-tensor
     exponents are massively compressible while mantissas are not);
  3. **Entropy-code** (lossless): each segment runs through a pluggable
     byte-stream codec (zlib by default; ``register_codec`` adds more).

The on-disk artifact is a single ``payload.bin`` — a fixed 12-byte preamble,
a canonical-JSON header describing every tensor segment, and the coded
segment bytes — next to the usual ``manifest.json``. The full layout, field
tables, and versioning rules live in docs/ARCHITECTURE.md ("Bundle wire
format"); keep that spec in sync with this module.

Decoding is split so servers can defer the lossy inverse: ``decode_payload``
undoes only the lossless stages and returns :class:`QuantTensor` parts, and
``dequantize_jnp`` runs the dequantization math inside a jitted computation
(the serve engine fuses it into MCNC expansion, so its ExpansionCache can
hold int8 codes instead of float32 state — see repro.serve.engine).
"""
from __future__ import annotations

import dataclasses
import json
import struct
import zlib
from typing import Callable

import numpy as np

MAGIC = b"MCNC"
WIRE_VERSION = 2
# preamble: 4s magic, u16 wire version, u16 flags, u32 stored header bytes,
# u32 raw header bytes (flag bit 0: header JSON is zlib-compressed — tensor
# records are repetitive enough that this is ~10x, and for MCNC-sized
# bundles the header would otherwise rival the int8 payload itself)
PREAMBLE = struct.Struct("<4sHHII")
FLAG_HEADER_ZLIB = 1

QUANT_SCHEMES = ("none", "int8", "nf4")

# nf4 codebook (QLoRA appendix E): the 16 quantiles of N(0, 1) normalized to
# [-1, 1] — the information-theoretically optimal 4-bit grid for normally
# distributed weights, which MCNC alpha perturbations empirically are
NF4_CODES = np.array([
    -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
    -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
    0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
    0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
    0.7229568362236023, 1.0], dtype=np.float32)

NF4_BLOCK = 64


def canonical_json(obj) -> str:
    """Deterministic JSON (sorted keys, no whitespace) — hash/header input."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


# ---------------------------------------------------------------------------
# Pluggable lossless byte-stream codecs.
# ---------------------------------------------------------------------------

_CODECS: dict[str, tuple[Callable[[bytes], bytes],
                         Callable[[bytes], bytes]]] = {}


def register_codec(name: str, encode: Callable[[bytes], bytes],
                   decode: Callable[[bytes], bytes]):
    """Register a lossless byte-stream codec under `name`.

    `encode`/`decode` map bytes -> bytes and must round-trip exactly. The
    name is recorded per segment in the v2 header, so decoders pick the
    right inverse without any out-of-band knowledge."""
    _CODECS[name] = (encode, decode)


def get_codec(name: str) -> tuple[Callable[[bytes], bytes],
                                  Callable[[bytes], bytes]]:
    """Look up a registered codec; raises ValueError on unknown names."""
    try:
        return _CODECS[name]
    except KeyError:
        raise ValueError(f"unknown bundle codec {name!r} "
                         f"(registered: {sorted(_CODECS)})") from None


register_codec("raw", lambda b: b, lambda b: b)
register_codec("zlib", lambda b: zlib.compress(b, 6), zlib.decompress)


# ---------------------------------------------------------------------------
# Byte-grouping (ZipNN-style lossless transform).
# ---------------------------------------------------------------------------

def group_bytes(raw: bytes, itemsize: int) -> bytes:
    """Regroup an array's bytes into per-byte planes (byte 0 of every
    element, then byte 1 of every element, ...). For IEEE floats this
    clusters the low-entropy sign/exponent bytes away from the high-entropy
    mantissa bytes, which is worth 2-4x to the downstream entropy coder on
    float scale planes. Lossless; inverse is ungroup_bytes."""
    if itemsize <= 1 or not raw:
        return raw
    a = np.frombuffer(raw, np.uint8).reshape(-1, itemsize)
    return np.ascontiguousarray(a.T).tobytes()


def ungroup_bytes(raw: bytes, itemsize: int) -> bytes:
    """Inverse of group_bytes."""
    if itemsize <= 1 or not raw:
        return raw
    a = np.frombuffer(raw, np.uint8).reshape(itemsize, -1)
    return np.ascontiguousarray(a.T).tobytes()


# ---------------------------------------------------------------------------
# Quantization schemes.
# ---------------------------------------------------------------------------

def quantize_int8(arr: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-tensor symmetric int8: codes in [-127, 127], one fp16 scale.

    The scale is rounded to fp16 BEFORE the codes are computed, so encode
    and decode agree on the exact grid (otherwise the fp16 rounding of the
    scale would add a second, unaccounted error term). Max abs error is
    scale/2 plus the fp16 rounding of the max element."""
    a = np.asarray(arr, np.float32).reshape(-1)
    amax = float(np.max(np.abs(a))) if a.size else 0.0
    scale = np.float16(min(amax / 127.0, 6.0e4))   # clamp: no inf in fp16
    s = np.float32(scale)
    if s == 0.0:
        codes = np.zeros(a.shape, np.int8)
    else:
        codes = np.clip(np.rint(a / s), -127, 127).astype(np.int8)
    return codes, np.asarray(scale, np.float16).reshape(())


def dequantize_int8_np(codes: np.ndarray, scale: np.ndarray) -> np.ndarray:
    """codes * scale in float32 (bit-identical to the jnp path on CPU)."""
    return codes.astype(np.float32) * np.float32(scale)


def quantize_nf4(arr: np.ndarray, block: int = NF4_BLOCK
                 ) -> tuple[np.ndarray, np.ndarray]:
    """nf4-style block quantization: per-block fp16 absmax + 4-bit codebook
    indices packed two per byte. Returns (packed_codes, absmax_per_block)."""
    a = np.asarray(arr, np.float32).reshape(-1)
    n = a.size
    nblocks = max(1, -(-n // block))
    pad = nblocks * block - n
    if pad:
        a = np.concatenate([a, np.zeros((pad,), np.float32)])
    blocks = a.reshape(nblocks, block)
    absmax = np.float16(np.clip(np.max(np.abs(blocks), axis=1), 0, 6.0e4))
    s = absmax.astype(np.float32)
    norm = blocks / np.where(s == 0.0, 1.0, s)[:, None]
    idx = np.argmin(np.abs(norm[:, :, None] - NF4_CODES[None, None, :]),
                    axis=2).astype(np.uint8).reshape(-1)
    packed = ((idx[0::2] << 4) | idx[1::2]).astype(np.uint8)
    return packed, absmax


def dequantize_nf4_np(packed: np.ndarray, absmax: np.ndarray, numel: int,
                      block: int = NF4_BLOCK) -> np.ndarray:
    """Inverse of quantize_nf4 (flat float32 of length `numel`)."""
    hi = (packed >> 4).astype(np.uint8)
    lo = (packed & 0xF).astype(np.uint8)
    idx = np.stack([hi, lo], axis=1).reshape(-1)
    vals = NF4_CODES[idx] * np.repeat(absmax.astype(np.float32), block)
    return vals[:numel]


# ---------------------------------------------------------------------------
# Per-tensor container.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QuantTensor:
    """One tensor's decoded-but-not-dequantized representation.

    `parts` holds the scheme's raw arrays ({"raw": x} for scheme "none",
    {"codes", "scales"} for int8/nf4); `meta` is the hashable static
    description a jitted dequantizer closes over."""
    scheme: str                      # "none" | "int8" | "nf4"
    dtype: str                       # original dtype string, e.g. "float32"
    shape: tuple[int, ...]
    block: int                       # nf4 block size (0 otherwise)
    parts: dict[str, np.ndarray]

    @property
    def meta(self) -> tuple:
        """Hashable (scheme, dtype, shape, block) — static arg for jit."""
        return (self.scheme, self.dtype, tuple(self.shape), self.block)

    def dequantize(self) -> np.ndarray:
        """Host-side lossy inverse; returns the original-dtype ndarray."""
        return dequantize_np(self.parts, self.meta)


def dequantize_np(parts: dict[str, np.ndarray], meta: tuple) -> np.ndarray:
    """Numpy dequantization (mirrors dequantize_jnp bit-for-bit on CPU)."""
    scheme, dtype, shape, block = meta
    if scheme == "none":
        return np.asarray(parts["raw"]).reshape(shape)
    if scheme == "int8":
        out = dequantize_int8_np(np.asarray(parts["codes"]),
                                 np.asarray(parts["scales"]))
    elif scheme == "nf4":
        numel = int(np.prod(shape, dtype=np.int64)) if shape else 1
        out = dequantize_nf4_np(np.asarray(parts["codes"]),
                                np.asarray(parts["scales"]), numel, block)
    else:
        raise ValueError(f"unknown quant scheme {scheme!r}")
    return out.reshape(shape).astype(np.dtype(dtype))


def dequantize_jnp(parts: dict, meta: tuple):
    """jnp dequantization for use INSIDE a jitted computation.

    `parts` are device arrays (the serve engine's quantized cache values),
    `meta` the hashable QuantTensor.meta. The int8 path is exactly
    codes.f32 * scale.f32, so host (numpy) and device (jitted, CPU/TPU)
    dequantization agree bitwise for int8 — the quantized-cache engine is
    token-identical to dequantize-on-load by construction, not by luck."""
    import jax.numpy as jnp          # deferred: keep this module jax-free
    scheme, dtype, shape, block = meta
    if scheme == "none":
        return jnp.reshape(parts["raw"], shape)
    if scheme == "int8":
        out = (parts["codes"].astype(jnp.float32)
               * parts["scales"].astype(jnp.float32))
    elif scheme == "nf4":
        numel = int(np.prod(shape, dtype=np.int64)) if shape else 1
        packed = parts["codes"]
        idx = jnp.stack([packed >> 4, packed & 0xF], axis=1).reshape(-1)
        vals = jnp.asarray(NF4_CODES)[idx]
        amax = jnp.repeat(parts["scales"].astype(jnp.float32), block)
        out = (vals * amax)[:numel]
    else:
        raise ValueError(f"unknown quant scheme {scheme!r}")
    return out.reshape(shape).astype(jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# Device-layout rows codec (serving stacks).
#
# The wire codec above quantizes whole tensors for storage. The serving
# engine needs a different granularity: its per-slot stacked adapter buffers
# are written one SLOT at a time (incremental `.at[:, slot]` writes) and read
# one LAYER at a time (lax.scan over the layer axis), so each leading-axis
# row must be independently decodable — one scale (plane) per row, never a
# tensor-global statistic that a single-slot write would invalidate. These
# "rows" functions quantize every leading-axis row of an array on its own:
# stacking rows, slicing rows, and concatenating rows all commute with the
# codec. np/jnp twins mirror each other the same way the wire codec's do —
# int8 bit-equal, nf4 equal on CPU — so host-side references and the jitted
# serving path agree (tests/test_bundle_codec.py pins this).
# ---------------------------------------------------------------------------

def rows_meta(scheme: str, trailing_shape: tuple[int, ...],
              block: int = NF4_BLOCK) -> tuple:
    """Hashable static meta for the rows codec: (scheme, trailing_shape,
    block). The leading row count is NOT part of the meta — it is carried by
    the parts arrays themselves, which is what lets one meta describe the
    same adapter leaf at every stacking depth (a (L, B, m, r) slot stack and
    its (B, m, r) per-layer slice share a meta)."""
    if scheme not in ("int8", "nf4"):
        raise ValueError(f"rows codec supports int8/nf4, got {scheme!r}")
    return (scheme, tuple(int(d) for d in trailing_shape),
            int(block) if scheme == "nf4" else 0)


def rows_part_shapes(meta: tuple, lead: tuple[int, ...]
                     ) -> dict[str, tuple[tuple[int, ...], str]]:
    """{"codes"/"scales": (shape, dtype_str)} for rows parts with the given
    leading (row/stack) dims — the engine sizes its persistent coded stack
    buffers from this. All-zero parts dequantize to exactly 0.0 under both
    schemes (the scale factor is zero), which is what keeps freed-slot
    zeroing a plain zero-write."""
    scheme, trailing, block = meta
    lead = tuple(int(d) for d in lead)
    numel = int(np.prod(trailing, dtype=np.int64)) if trailing else 1
    if scheme == "int8":
        return {"codes": (lead + trailing, "int8"),
                "scales": (lead, "float16")}
    nblocks = max(1, -(-numel // block))
    return {"codes": (lead + (nblocks * block // 2,), "uint8"),
            "scales": (lead + (nblocks,), "float16")}


def quantize_rows_np(arr: np.ndarray, scheme: str,
                     block: int = NF4_BLOCK) -> dict[str, np.ndarray]:
    """Quantize each leading-axis row of `arr` independently (numpy).

    int8: {"codes" (L, *S) int8, "scales" (L,) fp16} — one symmetric scale
    per row, fixed in fp16 BEFORE the codes (same grid contract as
    quantize_int8). nf4: rows are flattened, zero-padded to a block
    multiple, and block-quantized — {"codes" (L, pad//2) uint8 packed,
    "scales" (L, nblocks) fp16}."""
    a = np.asarray(arr, np.float32)
    lead = a.shape[0]
    flat = a.reshape(lead, -1)
    if scheme == "int8":
        amax = np.max(np.abs(flat), axis=1) if flat.shape[1] else \
            np.zeros((lead,), np.float32)
        scales = np.clip(amax / 127.0, 0.0, 6.0e4).astype(np.float16)
        s = scales.astype(np.float32)
        codes = np.where(
            (s == 0.0)[:, None], np.int8(0),
            np.clip(np.rint(flat / np.where(s == 0.0, 1.0, s)[:, None]),
                    -127, 127).astype(np.int8))
        return {"codes": codes.reshape(a.shape),
                "scales": scales}
    if scheme == "nf4":
        n = flat.shape[1]
        nblocks = max(1, -(-n // block))
        pad = nblocks * block - n
        if pad:
            flat = np.concatenate(
                [flat, np.zeros((lead, pad), np.float32)], axis=1)
        blocks = flat.reshape(lead, nblocks, block)
        absmax = np.clip(np.max(np.abs(blocks), axis=2),
                         0.0, 6.0e4).astype(np.float16)
        s = absmax.astype(np.float32)
        norm = blocks / np.where(s == 0.0, 1.0, s)[:, :, None]
        idx = np.argmin(np.abs(norm[..., None] - NF4_CODES[None, None, None]),
                        axis=-1).astype(np.uint8).reshape(lead, -1)
        packed = ((idx[:, 0::2] << 4) | idx[:, 1::2]).astype(np.uint8)
        return {"codes": packed, "scales": absmax}
    raise ValueError(f"rows codec supports int8/nf4, got {scheme!r}")


def dequantize_rows_np(parts: dict[str, np.ndarray], meta: tuple
                       ) -> np.ndarray:
    """Numpy inverse of quantize_rows_np: (L, *meta.trailing) float32."""
    scheme, trailing, block = meta
    codes = np.asarray(parts["codes"])
    scales = np.asarray(parts["scales"])
    lead = codes.shape[0]
    if scheme == "int8":
        return (codes.astype(np.float32).reshape(lead, -1)
                * scales.astype(np.float32)[:, None]
                ).reshape((lead,) + tuple(trailing))
    numel = int(np.prod(trailing, dtype=np.int64)) if trailing else 1
    hi = (codes >> 4).astype(np.uint8)
    lo = (codes & 0xF).astype(np.uint8)
    idx = np.stack([hi, lo], axis=2).reshape(lead, -1)
    vals = NF4_CODES[idx] * np.repeat(scales.astype(np.float32),
                                      block, axis=1)
    return vals[:, :numel].reshape((lead,) + tuple(trailing))


def quantize_rows_jnp(arr, scheme: str, block: int = NF4_BLOCK) -> dict:
    """jnp twin of quantize_rows_np for use inside a jitted computation
    (the engine quantizes effective adapter leaves on device at admission).
    Same math, same fp16 rounding points: int8 codes/scales are bit-equal
    to the numpy path, so a host-side reference restack reproduces the
    device-resident coded stacks exactly."""
    import jax.numpy as jnp          # deferred: keep this module jax-free
    a = jnp.asarray(arr, jnp.float32)
    lead = a.shape[0]
    flat = a.reshape(lead, -1)
    if scheme == "int8":
        amax = jnp.max(jnp.abs(flat), axis=1) if flat.shape[1] else \
            jnp.zeros((lead,), jnp.float32)
        scales = jnp.clip(amax / 127.0, 0.0, 6.0e4).astype(jnp.float16)
        s = scales.astype(jnp.float32)
        codes = jnp.where(
            (s == 0.0)[:, None], jnp.int8(0),
            jnp.clip(jnp.rint(flat / jnp.where(s == 0.0, 1.0, s)[:, None]),
                     -127, 127).astype(jnp.int8))
        return {"codes": codes.reshape(a.shape), "scales": scales}
    if scheme == "nf4":
        n = flat.shape[1]
        nblocks = max(1, -(-n // block))
        pad = nblocks * block - n
        if pad:
            flat = jnp.concatenate(
                [flat, jnp.zeros((lead, pad), jnp.float32)], axis=1)
        blocks = flat.reshape(lead, nblocks, block)
        absmax = jnp.clip(jnp.max(jnp.abs(blocks), axis=2),
                          0.0, 6.0e4).astype(jnp.float16)
        s = absmax.astype(jnp.float32)
        norm = blocks / jnp.where(s == 0.0, 1.0, s)[:, :, None]
        idx = jnp.argmin(
            jnp.abs(norm[..., None] - jnp.asarray(NF4_CODES)),
            axis=-1).astype(jnp.uint8).reshape(lead, -1)
        packed = ((idx[:, 0::2] << 4) | idx[:, 1::2]).astype(jnp.uint8)
        return {"codes": packed, "scales": absmax}
    raise ValueError(f"rows codec supports int8/nf4, got {scheme!r}")


def dequantize_rows_jnp(parts: dict, meta: tuple):
    """jnp inverse of the rows codec for use INSIDE a jitted computation.

    int8 is exactly codes.f32 * scale.f32 per row — the same elementwise op
    the fused adapter-apply kernels run in VMEM before their matmul, which
    is why fused-dequant serving is bit-equal to dequantize-then-matmul
    (token-identical by construction, not by luck)."""
    import jax.numpy as jnp          # deferred: keep this module jax-free
    scheme, trailing, block = meta
    codes = parts["codes"]
    scales = parts["scales"]
    lead = codes.shape[0]
    if scheme == "int8":
        return (codes.astype(jnp.float32).reshape(lead, -1)
                * scales.astype(jnp.float32)[:, None]
                ).reshape((lead,) + tuple(trailing))
    numel = int(np.prod(trailing, dtype=np.int64)) if trailing else 1
    idx = jnp.stack([codes >> 4, codes & 0xF], axis=2).reshape(lead, -1)
    # scale application via (rows, nblocks, block) broadcast, not
    # jnp.repeat: same per-element code*scale multiply (bit-equal to the
    # numpy path), one gather fewer on the decode hot path
    vals = (jnp.asarray(NF4_CODES)[idx].reshape(lead, -1, block)
            * scales.astype(jnp.float32)[:, :, None]).reshape(lead, -1)
    return vals[:, :numel].reshape((lead,) + tuple(trailing))


# ---------------------------------------------------------------------------
# v2 payload encode/decode.
# ---------------------------------------------------------------------------

def _quantize_tensor(arr: np.ndarray, quant: str) -> QuantTensor:
    """Apply the bundle-level quant scheme to one tensor. Only floating
    tensors are quantized; integer/bool tensors ship raw (lossless) under
    any scheme, so a mixed tree never silently corrupts index arrays."""
    shape = tuple(int(d) for d in arr.shape)
    if quant == "none" or not np.issubdtype(arr.dtype, np.floating):
        return QuantTensor("none", str(arr.dtype), shape, 0,
                           {"raw": np.ascontiguousarray(arr)})
    if quant == "int8":
        codes, scale = quantize_int8(arr)
        return QuantTensor("int8", str(arr.dtype), shape, 0,
                           {"codes": codes, "scales": scale})
    if quant == "nf4":
        codes, absmax = quantize_nf4(arr)
        return QuantTensor("nf4", str(arr.dtype), shape, NF4_BLOCK,
                           {"codes": codes, "scales": absmax})
    raise ValueError(f"unknown quant scheme {quant!r} "
                     f"(expected one of {QUANT_SCHEMES})")


def encode_arrays(arrays: dict[str, np.ndarray], *, quant: str = "none",
                  codec: str = "zlib") -> tuple[bytes, dict]:
    """Encode a flat {name: ndarray} dict into a v2 payload.

    Returns (payload_bytes, header_dict). The payload embeds the header, so
    hashing the payload covers the codec metadata — see
    manager.bundle_hash_v2. Tensors are laid out in sorted-name order;
    every segment records its own codec, byte-group width, offset, and
    coded/raw byte counts (docs/ARCHITECTURE.md has the field tables)."""
    enc, _ = get_codec(codec)
    tensors_hdr: list[dict] = []
    blobs: list[bytes] = []
    offset = 0
    for name in sorted(arrays):
        qt = _quantize_tensor(np.asarray(arrays[name]), quant)
        segments = []
        for role in sorted(qt.parts):
            part = np.ascontiguousarray(qt.parts[role])
            itemsize = part.dtype.itemsize
            raw = part.tobytes()
            grouped = group_bytes(raw, itemsize)
            coded = enc(grouped)
            segments.append({
                "role": role, "dtype": str(part.dtype),
                "shape": [int(d) for d in part.shape],
                "byte_group": itemsize if itemsize > 1 else 0,
                "codec": codec, "offset": offset,
                "nbytes": len(coded), "raw_nbytes": len(raw)})
            blobs.append(coded)
            offset += len(coded)
        tensors_hdr.append({
            "name": name, "scheme": qt.scheme, "dtype": qt.dtype,
            "shape": list(qt.shape), "block": qt.block,
            "segments": segments})
    header = {"version": WIRE_VERSION, "quant": quant, "codec": codec,
              "tensors": tensors_hdr}
    hjson = canonical_json(header).encode()
    hcomp = zlib.compress(hjson, 6)
    payload = (PREAMBLE.pack(MAGIC, WIRE_VERSION, FLAG_HEADER_ZLIB,
                             len(hcomp), len(hjson))
               + hcomp + b"".join(blobs))
    return payload, header


def decode_payload(payload: bytes) -> tuple[dict[str, QuantTensor], dict]:
    """Parse a v2 payload back into {name: QuantTensor} + the header dict.

    Undoes only the LOSSLESS stages (codec + byte-grouping); the caller
    decides when the lossy dequantization runs (host-side via
    QuantTensor.dequantize, or on device via dequantize_jnp). Raises
    IOError on a bad magic, an unsupported wire version, or truncation —
    readers must reject unknown future versions, not guess (versioning
    rules in docs/ARCHITECTURE.md)."""
    if len(payload) < PREAMBLE.size:
        raise IOError("v2 payload truncated: shorter than the preamble")
    magic, version, flags, hlen, hraw = PREAMBLE.unpack_from(payload, 0)
    if magic != MAGIC:
        raise IOError(f"bad bundle magic {magic!r} (want {MAGIC!r})")
    if version != WIRE_VERSION:
        raise IOError(f"unsupported bundle wire version {version} "
                      f"(this reader speaks {WIRE_VERSION})")
    body = PREAMBLE.size
    if len(payload) < body + hlen:
        raise IOError("v2 payload truncated: header extends past EOF")
    hjson = payload[body:body + hlen]
    try:
        if flags & FLAG_HEADER_ZLIB:
            hjson = zlib.decompress(hjson)
            if len(hjson) != hraw:
                raise IOError(f"v2 header decompressed to {len(hjson)} "
                              f"bytes, preamble says {hraw}")
        header = json.loads(hjson.decode())
    except (zlib.error, UnicodeDecodeError,
            json.JSONDecodeError) as e:
        raise IOError(f"v2 payload header corrupt: {e}") from None
    seg0 = body + hlen
    out: dict[str, QuantTensor] = {}
    for t in header["tensors"]:
        parts = {}
        for seg in t["segments"]:
            lo = seg0 + seg["offset"]
            hi = lo + seg["nbytes"]
            if hi > len(payload):
                raise IOError(f"v2 payload truncated: segment "
                              f"{t['name']}/{seg['role']} past EOF")
            _, dec = get_codec(seg["codec"])
            raw = ungroup_bytes(dec(payload[lo:hi]), seg["byte_group"] or 1)
            if len(raw) != seg["raw_nbytes"]:
                raise IOError(f"segment {t['name']}/{seg['role']} decoded "
                              f"to {len(raw)} bytes, header says "
                              f"{seg['raw_nbytes']}")
            parts[seg["role"]] = np.frombuffer(
                raw, np.dtype(seg["dtype"])).reshape(seg["shape"])
        out[t["name"]] = QuantTensor(t["scheme"], t["dtype"],
                                     tuple(t["shape"]), int(t["block"]),
                                     parts)
    return out, header


def dequantize_arrays(tensors: dict[str, QuantTensor]
                      ) -> dict[str, np.ndarray]:
    """Host-side dequantization of a whole decoded payload."""
    return {name: qt.dequantize() for name, qt in tensors.items()}
