"""Fault-tolerant checkpointing + versioned on-disk artifact formats.

Properties needed at 1000+ nodes and implemented here:
  * atomic: write to a temp dir, fsync, rename — a crash mid-write never
    corrupts the latest checkpoint;
  * integrity: content hash stored in the manifest and verified on restore;
  * auto-resume: latest-step discovery + deterministic (seed, step) data
    streams make restart a pure function of the checkpoint;
  * MCNC-native: in mcnc mode the trainable state is (generator seed, alpha,
    beta) — a 405B model's task state checkpoints in ~MBs (the paper's
    storage/communication story applied to fault tolerance);
  * async: an optional background thread moves serialization off the step
    loop (save() returns immediately after host-side array capture).

Artifact formats (dispatch on manifest["format"], absent == 1):
  * v1 — raw ``arrays.npz`` (uncompressed) + ``manifest.json``; the hash
    covers ONLY the tensor payload (name/dtype/shape/bytes), so manifest
    metadata is not integrity-protected. Kept readable forever.
  * v2 — quantized + entropy-coded ``payload.bin`` (repro.checkpoint.codec)
    + ``manifest.json``; the hash covers the payload (which embeds the
    codec header) AND the protected manifest fields, closing v1's
    spoofable-metadata gap. docs/ARCHITECTURE.md specifies the wire layout.
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import tempfile
import threading
import time
from typing import Any

import jax
import numpy as np

from repro.checkpoint.codec import (QuantTensor, canonical_json,
                                    decode_payload, dequantize_arrays,
                                    encode_arrays)
from repro.core.reparam import flatten_with_paths, unflatten_paths

PyTree = Any

# manifest fields folded into the v2 bundle hash. Everything a loader TRUSTS
# (generator config, adapter config, versioning, codec identity) must be
# here: v1 only hashed the tensor payload, so flipping e.g. the generator
# seed in manifest.json went undetected while the arrays still verified.
PROTECTED_MANIFEST_KEYS = ("task_id", "version", "format", "codec", "quant",
                           "generator", "adapter", "metadata", "step",
                           "n_arrays")


def tree_to_arrays(tree: PyTree) -> dict[str, np.ndarray]:
    """Flatten a pytree to {path-with-|-separators: host ndarray}."""
    flat = flatten_with_paths(tree)
    out = {}
    for path, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        out[path.replace("/", "|")] = arr
    return out


def arrays_to_tree(arrays: dict[str, np.ndarray]) -> PyTree:
    """Inverse of tree_to_arrays."""
    return unflatten_paths({k.replace("|", "/"): v
                            for k, v in arrays.items()})


def _content_hash(arrays: dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for key in sorted(arrays):
        h.update(key.encode())
        h.update(str(arrays[key].dtype).encode())
        h.update(str(arrays[key].shape).encode())
        h.update(np.ascontiguousarray(arrays[key]).tobytes())
    return h.hexdigest()


def protected_manifest_blob(manifest: dict) -> bytes:
    """Canonical JSON of the integrity-protected manifest fields."""
    sub = {k: manifest[k] for k in PROTECTED_MANIFEST_KEYS if k in manifest}
    return canonical_json(sub).encode()


def bundle_hash_v2(payload: bytes, manifest: dict) -> str:
    """v2 bundle hash: protected manifest fields + the whole payload.

    The payload embeds the codec header (magic, wire version, per-segment
    codec/offsets), so the hash covers the header and codec metadata, not
    just the tensor bytes — editing the manifest's generator/adapter/codec
    fields or the payload header is detected, unlike format v1 where only
    the raw arrays were hashed."""
    h = hashlib.sha256()
    h.update(protected_manifest_blob(manifest))
    h.update(payload)
    return h.hexdigest()


def write_artifact(final_dir: str, arrays: dict[str, np.ndarray],
                   manifest_extra: dict | None = None, *, fmt: int = 1,
                   quant: str = "none", codec: str = "zlib") -> dict:
    """Atomically publish an artifact directory at `final_dir`.

    fmt=1 writes {arrays.npz, manifest.json} (raw fp32, hash over tensor
    payload only — the legacy layout, kept readable forever); fmt=2 writes
    {payload.bin, manifest.json} via repro.checkpoint.codec with `quant`
    ("none" | "int8" | "nf4") and lossless `codec` ("zlib" | "raw" | any
    register_codec name), hash over payload + protected manifest fields.

    Write to a temp dir next to the target, fsync, rename — a crash mid-write
    never leaves a partial artifact; an existing artifact is replaced whole.
    Shared by the checkpoint manager and the serving adapter registry
    (repro.serve). Returns the manifest dict.
    """
    if fmt not in (1, 2):
        raise ValueError(f"unknown artifact format {fmt!r}")
    if fmt == 1 and quant != "none":
        raise ValueError("format v1 cannot quantize; use fmt=2 (v1 exists "
                         "for byte-stable legacy artifacts only)")
    parent = os.path.dirname(os.path.abspath(final_dir)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=".tmp_artifact_", dir=parent)
    try:
        if fmt == 1:
            with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
                np.savez(f, **arrays)
                f.flush()
                os.fsync(f.fileno())
            manifest = {"hash": _content_hash(arrays), "time": time.time(),
                        "n_arrays": len(arrays)}
            manifest.update(manifest_extra or {})
        else:
            payload, _header = encode_arrays(arrays, quant=quant,
                                             codec=codec)
            with open(os.path.join(tmp, "payload.bin"), "wb") as f:
                f.write(payload)
                f.flush()
                os.fsync(f.fileno())
            manifest = {"format": 2, "quant": quant, "codec": codec,
                        "time": time.time(), "n_arrays": len(arrays)}
            manifest.update(manifest_extra or {})
            # hash LAST: it must cover the merged manifest_extra fields
            manifest["hash"] = bundle_hash_v2(payload, manifest)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        # Replace via rename-aside, not rmtree-then-rename: a crash between
        # those two would lose the live artifact entirely (fatal for the
        # registry's hot-swap of a serving bundle). The dot-prefixed aside
        # name keeps it invisible to directory listings.
        aside = None
        if os.path.exists(final_dir):
            aside = os.path.join(parent,
                                 "." + os.path.basename(final_dir) + ".old")
            if os.path.exists(aside):
                shutil.rmtree(aside)
            os.rename(final_dir, aside)
        try:
            os.rename(tmp, final_dir)   # atomic publish
        except Exception:
            if aside is not None and not os.path.exists(final_dir):
                os.rename(aside, final_dir)     # restore the old artifact
            raise
        if aside is not None:
            shutil.rmtree(aside, ignore_errors=True)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return manifest


def read_artifact(final_dir: str, *, verify: bool = True
                  ) -> tuple[dict[str, np.ndarray], dict]:
    """Read an artifact written by write_artifact; verify the content hash.

    Dispatches on manifest["format"] (absent == v1), so v1 and v2 artifacts
    load through the same call. v2 tensors are dequantized host-side here;
    use read_artifact_quantized to keep the coded representation."""
    manifest = _read_manifest(final_dir)
    if int(manifest.get("format", 1)) == 1:
        with np.load(os.path.join(final_dir, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        if verify:
            h = _content_hash(arrays)
            if h != manifest["hash"]:
                raise IOError(f"artifact {final_dir} corrupt: hash mismatch")
        return arrays, manifest
    tensors, manifest = _read_v2(final_dir, manifest, verify=verify)
    return dequantize_arrays(tensors), manifest


def read_artifact_quantized(final_dir: str, *, verify: bool = True
                            ) -> tuple[dict[str, QuantTensor], dict]:
    """Like read_artifact, but defer the lossy dequantization stage.

    Returns {name: QuantTensor} for EVERY format: v2 tensors keep their
    coded parts (int8/nf4 codes + fp16 scale planes), v1 (and v2 quant
    "none") tensors are wrapped as scheme-"none" QuantTensors — callers
    like the serve engine's quantized ExpansionCache handle one shape of
    data regardless of what is on disk."""
    manifest = _read_manifest(final_dir)
    if int(manifest.get("format", 1)) == 1:
        arrays, manifest = read_artifact(final_dir, verify=verify)
        tensors = {
            name: QuantTensor("none", str(a.dtype),
                              tuple(int(d) for d in a.shape), 0, {"raw": a})
            for name, a in arrays.items()}
        return tensors, manifest
    return _read_v2(final_dir, manifest, verify=verify)


def _read_manifest(final_dir: str) -> dict:
    with open(os.path.join(final_dir, "manifest.json")) as f:
        return json.load(f)


def _read_v2(final_dir: str, manifest: dict, *, verify: bool
             ) -> tuple[dict[str, QuantTensor], dict]:
    """Read + (optionally) verify a v2 payload against its manifest."""
    with open(os.path.join(final_dir, "payload.bin"), "rb") as f:
        payload = f.read()
    if verify and bundle_hash_v2(payload, manifest) != manifest["hash"]:
        raise IOError(f"artifact {final_dir} corrupt: v2 hash mismatch "
                      "(payload or protected manifest fields tampered)")
    tensors, header = decode_payload(payload)
    if verify and (header.get("quant") != manifest.get("quant")
                   or header.get("codec") != manifest.get("codec")):
        raise IOError(f"artifact {final_dir} corrupt: manifest codec "
                      "metadata disagrees with the payload header")
    return tensors, manifest


class CheckpointManager:
    """Step-numbered checkpoint store over write_artifact/read_artifact.

    fmt/quant/codec select the artifact format for NEW saves (default v1 for
    byte-stable history; pass fmt=2 to store quantized + entropy-coded
    task states — restore() reads either transparently)."""

    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = False, fmt: int = 1,
                 quant: str = "none", codec: str = "zlib"):
        self.dir = directory
        self.keep = keep
        self.fmt = fmt
        self.quant = quant
        self.codec = codec
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue | None = None
        self._worker = None
        self._errors: list[Exception] = []
        if async_save:
            self._q = queue.Queue(maxsize=2)
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def save(self, step: int, state: PyTree, metadata: dict | None = None):
        """Checkpoint `state` at `step` (async mode returns right after
        host-side array capture; errors surface on wait())."""
        arrays = tree_to_arrays(state)     # host capture happens now
        if self._q is not None:
            self._q.put((step, arrays, metadata or {}))
            return
        self._write(step, arrays, metadata or {})

    def _drain(self):
        while True:
            step, arrays, metadata = self._q.get()
            try:
                self._write(step, arrays, metadata)
            except Exception as e:   # surfaced on next wait()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def wait(self):
        """Block until queued async saves land; re-raise their errors."""
        if self._q is not None:
            self._q.join()
        if self._errors:
            raise self._errors[0]

    def _write(self, step: int, arrays: dict, metadata: dict):
        write_artifact(self._step_dir(step), arrays,
                       {"step": step, "metadata": metadata},
                       fmt=self.fmt, quant=self.quant, codec=self.codec)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        """Sorted steps with a manifest on disk."""
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                manifest = os.path.join(self.dir, name, "manifest.json")
                if os.path.exists(manifest):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        """Most recent checkpointed step, or None when empty."""
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, *, verify: bool = True
                ) -> tuple[int, PyTree, dict]:
        """(step, state, metadata) for `step` (default latest), verified
        and format-dispatched through read_artifact."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        arrays, manifest = read_artifact(self._step_dir(step), verify=verify)
        return step, arrays_to_tree(arrays), manifest.get("metadata", {})
