"""Fault-tolerant checkpointing.

Properties needed at 1000+ nodes and implemented here:
  * atomic: write to a temp dir, fsync, rename — a crash mid-write never
    corrupts the latest checkpoint;
  * integrity: content hash stored in the manifest and verified on restore;
  * auto-resume: latest-step discovery + deterministic (seed, step) data
    streams make restart a pure function of the checkpoint;
  * MCNC-native: in mcnc mode the trainable state is (generator seed, alpha,
    beta) — a 405B model's task state checkpoints in ~MBs (the paper's
    storage/communication story applied to fault tolerance);
  * async: an optional background thread moves serialization off the step
    loop (save() returns immediately after host-side array capture).
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import tempfile
import threading
import time
from typing import Any

import jax
import numpy as np

from repro.core.reparam import flatten_with_paths, unflatten_paths

PyTree = Any


def tree_to_arrays(tree: PyTree) -> dict[str, np.ndarray]:
    flat = flatten_with_paths(tree)
    out = {}
    for path, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        out[path.replace("/", "|")] = arr
    return out


def arrays_to_tree(arrays: dict[str, np.ndarray]) -> PyTree:
    return unflatten_paths({k.replace("|", "/"): v
                            for k, v in arrays.items()})


def _content_hash(arrays: dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for key in sorted(arrays):
        h.update(key.encode())
        h.update(str(arrays[key].dtype).encode())
        h.update(str(arrays[key].shape).encode())
        h.update(np.ascontiguousarray(arrays[key]).tobytes())
    return h.hexdigest()


def write_artifact(final_dir: str, arrays: dict[str, np.ndarray],
                   manifest_extra: dict | None = None) -> dict:
    """Atomically publish {arrays.npz, manifest.json} at `final_dir`.

    Write to a temp dir next to the target, fsync, rename — a crash mid-write
    never leaves a partial artifact; an existing artifact is replaced whole.
    The manifest records a content hash verified on read. Shared by the
    checkpoint manager and the serving adapter registry (repro.serve).
    Returns the manifest dict.
    """
    parent = os.path.dirname(os.path.abspath(final_dir)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=".tmp_artifact_", dir=parent)
    try:
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        manifest = {"hash": _content_hash(arrays), "time": time.time(),
                    "n_arrays": len(arrays)}
        manifest.update(manifest_extra or {})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        # Replace via rename-aside, not rmtree-then-rename: a crash between
        # those two would lose the live artifact entirely (fatal for the
        # registry's hot-swap of a serving bundle). The dot-prefixed aside
        # name keeps it invisible to directory listings.
        aside = None
        if os.path.exists(final_dir):
            aside = os.path.join(parent,
                                 "." + os.path.basename(final_dir) + ".old")
            if os.path.exists(aside):
                shutil.rmtree(aside)
            os.rename(final_dir, aside)
        try:
            os.rename(tmp, final_dir)   # atomic publish
        except Exception:
            if aside is not None and not os.path.exists(final_dir):
                os.rename(aside, final_dir)     # restore the old artifact
            raise
        if aside is not None:
            shutil.rmtree(aside, ignore_errors=True)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return manifest


def read_artifact(final_dir: str, *, verify: bool = True
                  ) -> tuple[dict[str, np.ndarray], dict]:
    """Read an artifact written by write_artifact; verify the content hash."""
    with open(os.path.join(final_dir, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(final_dir, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    if verify:
        h = _content_hash(arrays)
        if h != manifest["hash"]:
            raise IOError(f"artifact {final_dir} corrupt: hash mismatch")
    return arrays, manifest


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = False):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue | None = None
        self._worker = None
        self._errors: list[Exception] = []
        if async_save:
            self._q = queue.Queue(maxsize=2)
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def save(self, step: int, state: PyTree, metadata: dict | None = None):
        arrays = tree_to_arrays(state)     # host capture happens now
        if self._q is not None:
            self._q.put((step, arrays, metadata or {}))
            return
        self._write(step, arrays, metadata or {})

    def _drain(self):
        while True:
            step, arrays, metadata = self._q.get()
            try:
                self._write(step, arrays, metadata)
            except Exception as e:   # surfaced on next wait()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def wait(self):
        if self._q is not None:
            self._q.join()
        if self._errors:
            raise self._errors[0]

    def _write(self, step: int, arrays: dict, metadata: dict):
        write_artifact(self._step_dir(step), arrays,
                       {"step": step, "metadata": metadata})
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                manifest = os.path.join(self.dir, name, "manifest.json")
                if os.path.exists(manifest):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, *, verify: bool = True
                ) -> tuple[int, PyTree, dict]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        arrays, manifest = read_artifact(self._step_dir(step), verify=verify)
        return step, arrays_to_tree(arrays), manifest.get("metadata", {})
