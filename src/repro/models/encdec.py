"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The audio frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings (B, T_enc, d). Decoder layers add cross-attention
against the encoder output; decode keeps a self-attention KV cache plus
per-layer cross K/V computed once at prefill.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.adapters import dense
from repro.layers.attention import (blocked_attention, cross_attention,
                                    decode_attention, masked_cache_write)
from repro.layers.mlp import swiglu
from repro.layers.norms import rms_norm
from repro.layers.rope import apply_rope
from repro.models.lm import _uinit
from repro.sharding.rules import shard, shard_cache

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str = "encdec"
    enc_layers: int = 12
    dec_layers: int = 12
    d_model: int = 1024
    n_heads: int = 16
    n_kv_heads: int = 16
    head_dim: int = 64
    d_ff: int = 4096
    vocab: int = 256206
    rope_theta: float = 10000.0
    attn_chunk: int = 512
    param_dtype: str = "float32"
    remat: bool = True


def _init_enc_layer(cfg: EncDecConfig, key: Array) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = iter(jax.random.split(key, 8))
    return {
        "ln1_scale": jnp.ones((d,), dtype),
        "ln2_scale": jnp.ones((d,), dtype),
        "wq": _uinit(next(ks), (d, hq * hd), d, dtype),
        "wk": _uinit(next(ks), (d, hkv * hd), d, dtype),
        "wv": _uinit(next(ks), (d, hkv * hd), d, dtype),
        "wo": _uinit(next(ks), (hq * hd, d), hq * hd, dtype),
        "w_gate": _uinit(next(ks), (d, cfg.d_ff), d, dtype),
        "w_up": _uinit(next(ks), (d, cfg.d_ff), d, dtype),
        "w_down": _uinit(next(ks), (cfg.d_ff, d), cfg.d_ff, dtype),
    }


def _init_dec_layer(cfg: EncDecConfig, key: Array) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = iter(jax.random.split(key, 12))
    p = _init_enc_layer(cfg, next(ks))
    p.update({
        "ln_cross_scale": jnp.ones((d,), dtype),
        "wq_cross": _uinit(next(ks), (d, hq * hd), d, dtype),
        "wk_cross": _uinit(next(ks), (d, hkv * hd), d, dtype),
        "wv_cross": _uinit(next(ks), (d, hkv * hd), d, dtype),
        "wo_cross": _uinit(next(ks), (hq * hd, d), hq * hd, dtype),
    })
    return p


def init_params(cfg: EncDecConfig, key: Array) -> PyTree:
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_head, ke, kd = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: _init_enc_layer(cfg, k))(
        jax.random.split(ke, cfg.enc_layers))
    dec = jax.vmap(lambda k: _init_dec_layer(cfg, k))(
        jax.random.split(kd, cfg.dec_layers))
    return {
        "enc_layers": enc,
        "dec_layers": dec,
        "embed": jax.random.normal(k_emb, (cfg.vocab, cfg.d_model),
                                   dtype) * 0.02,
        "enc_norm_scale": jnp.ones((cfg.d_model,), dtype),
        "dec_norm_scale": jnp.ones((cfg.d_model,), dtype),
        "lm_head": _uinit(k_head, (cfg.d_model, cfg.vocab), cfg.d_model,
                          dtype),
    }


def param_specs(cfg: EncDecConfig) -> PyTree:
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


def _proj_qkv(x, p, cfg, positions, prefix="", rope=True):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(x, p[f"wq{prefix}"], p.get(f"wq{prefix}_lora_a"),
              p.get(f"wq{prefix}_lora_b")).reshape(b, s, hq, hd)
    k = dense(x, p[f"wk{prefix}"], p.get(f"wk{prefix}_lora_a"),
              p.get(f"wk{prefix}_lora_b")).reshape(b, s, hkv, hd)
    v = dense(x, p[f"wv{prefix}"], p.get(f"wv{prefix}_lora_a"),
              p.get(f"wv{prefix}_lora_b")).reshape(b, s, hkv, hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return shard(q, "act_bthd"), shard(k, "act_bthd"), shard(v, "act_bthd")


def _out(o, p, cfg, prefix=""):
    b, s = o.shape[:2]
    o = o.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return dense(o, p[f"wo{prefix}"], p.get(f"wo{prefix}_lora_a"),
                 p.get(f"wo{prefix}_lora_b"))


def encode(cfg: EncDecConfig, params: PyTree, frames: Array) -> Array:
    """frames: (B, T_enc, d) stub embeddings -> encoder states."""
    x = shard(frames.astype(jnp.dtype(cfg.param_dtype)), "act_btd")
    positions = jnp.arange(x.shape[1])

    def body(h, lp):
        hh = rms_norm(h, lp["ln1_scale"])
        q, k, v = _proj_qkv(hh, lp, cfg, positions)
        a = blocked_attention(q, k, v, chunk=cfg.attn_chunk, causal=False)
        h = h + _out(a, lp, cfg)
        h2 = rms_norm(h, lp["ln2_scale"])
        h = h + swiglu(h2, lp)
        return shard(h, "act_btd"), None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm_scale"])


def _dec_layer_train(cfg, h, lp, enc_kv, positions):
    enc_k, enc_v = enc_kv
    hh = rms_norm(h, lp["ln1_scale"])
    q, k, v = _proj_qkv(hh, lp, cfg, positions)
    a = blocked_attention(q, k, v, chunk=cfg.attn_chunk, causal=True)
    h = h + _out(a, lp, cfg)
    hc = rms_norm(h, lp["ln_cross_scale"])
    qc = dense(hc, lp["wq_cross"], lp.get("wq_cross_lora_a"),
               lp.get("wq_cross_lora_b"))
    b, s = hc.shape[:2]
    qc = qc.reshape(b, s, cfg.n_heads, cfg.head_dim)
    c = blocked_attention(qc, enc_k, enc_v, chunk=cfg.attn_chunk,
                          causal=False)
    h = h + _out(c, lp, cfg, prefix="_cross")
    h2 = rms_norm(h, lp["ln2_scale"])
    return h + swiglu(h2, lp)


def _enc_kv(cfg, lp, enc_states):
    b, t = enc_states.shape[:2]
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    ek = dense(enc_states, lp["wk_cross"]).reshape(b, t, hkv, hd)
    ev = dense(enc_states, lp["wv_cross"]).reshape(b, t, hkv, hd)
    return shard(ek, "act_bthd"), shard(ev, "act_bthd")


def forward(cfg: EncDecConfig, params: PyTree, frames: Array,
            tokens: Array) -> Array:
    """Training forward -> decoder logits (B, S_dec, vocab)."""
    enc_states = encode(cfg, params, frames)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "act_btd")
    positions = jnp.arange(x.shape[1])

    def body(h, lp):
        kv = _enc_kv(cfg, lp, enc_states)
        h = _dec_layer_train(cfg, h, lp, kv, positions)
        return shard(h, "act_btd"), None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rms_norm(x, params["dec_norm_scale"])
    return shard(dense(x, params["lm_head"]), "logits")


def loss_fn(cfg: EncDecConfig, params: PyTree, batch: dict
            ) -> tuple[Array, dict]:
    logits = forward(cfg, params, batch["frames"], batch["inputs"])
    targets = batch["targets"]
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    l32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(l32, axis=-1)
    picked = jnp.sum(l32 * jax.nn.one_hot(tgt, cfg.vocab, dtype=jnp.float32),
                     axis=-1)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = ((lse - picked) * mask).sum() / denom
    return loss, {"loss": loss, "tokens": denom}


def prefill(cfg: EncDecConfig, params: PyTree, frames: Array, tokens: Array,
            cache_cap: int) -> tuple[Array, PyTree]:
    """Encode + run decoder prompt. Cache: self K/V (dec) + cross K/V."""
    enc_states = encode(cfg, params, frames)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "act_btd")
    b, s = tokens.shape
    positions = jnp.arange(s)
    pad = cache_cap - s

    def body(h, lp):
        ek, ev = _enc_kv(cfg, lp, enc_states)
        hh = rms_norm(h, lp["ln1_scale"])
        q, k, v = _proj_qkv(hh, lp, cfg, positions)
        a = blocked_attention(q, k, v, chunk=cfg.attn_chunk, causal=True)
        h = h + _out(a, lp, cfg)
        hc = rms_norm(h, lp["ln_cross_scale"])
        qc = dense(hc, lp["wq_cross"]).reshape(b, s, cfg.n_heads,
                                               cfg.head_dim)
        c = blocked_attention(qc, ek, ev, chunk=cfg.attn_chunk, causal=False)
        h = h + _out(c, lp, cfg, prefix="_cross")
        h2 = rms_norm(h, lp["ln2_scale"])
        h = h + swiglu(h2, lp)
        lc = {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))
                           ).transpose(0, 2, 1, 3),
              "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))
                           ).transpose(0, 2, 1, 3),
              "ek": ek.transpose(0, 2, 1, 3),
              "ev": ev.transpose(0, 2, 1, 3)}
        return shard(h, "act_btd"), lc

    x, cache = jax.lax.scan(body, x, params["dec_layers"])
    x_last = rms_norm(x[:, -1:], params["dec_norm_scale"])
    logits = dense(x_last, params["lm_head"])[:, 0]
    return logits, cache


def decode_step(cfg: EncDecConfig, params: PyTree, cache: PyTree,
                tokens: Array, pos: Array) -> tuple[Array, PyTree]:
    x = jnp.take(params["embed"], tokens[:, None], axis=0)

    # Cache in the scan carry (in-place update) — see models/lm.decode_step.
    cache = shard_cache(cache)

    def body(carry, inp):
        h, full_cache = carry
        lp, idx = inp
        lc = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0,
                                                   keepdims=False),
            full_cache)
        hh = rms_norm(h, lp["ln1_scale"])
        q, k, v = _proj_qkv(hh, lp, cfg, pos[None])
        kc = masked_cache_write(lc["k"], k.transpose(0, 2, 1, 3), pos,
                                axis=2)
        vc = masked_cache_write(lc["v"], v.transpose(0, 2, 1, 3), pos,
                                axis=2)
        a = decode_attention(q, kc, vc, pos + 1)
        h = h + _out(a, lp, cfg)
        hc = rms_norm(h, lp["ln_cross_scale"])
        qc = dense(hc, lp["wq_cross"]).reshape(h.shape[0], 1, cfg.n_heads,
                                               cfg.head_dim)
        c = cross_attention(qc, lc["ek"].transpose(0, 2, 1, 3),
                            lc["ev"].transpose(0, 2, 1, 3))
        h = h + _out(c, lp, cfg, prefix="_cross")
        h2 = rms_norm(h, lp["ln2_scale"])
        h = h + swiglu(h2, lp)
        new_lc = {"k": kc, "v": vc, "ek": lc["ek"], "ev": lc["ev"]}
        full_cache = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), idx, 0),
            full_cache, new_lc)
        return (h, shard_cache(full_cache)), None

    (x, new_cache), _ = jax.lax.scan(
        body, (x, cache),
        (params["dec_layers"], jnp.arange(cfg.dec_layers)))
    x = rms_norm(x[:, -1:], params["dec_norm_scale"])
    logits = dense(x, params["lm_head"])[:, 0]
    return logits, new_cache


def init_cache(cfg: EncDecConfig, batch: int, cache_cap: int, enc_len: int,
               dtype=jnp.bfloat16) -> PyTree:
    l = cfg.dec_layers
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    z = lambda shape: jnp.zeros((l,) + shape, dtype)
    # head-major at rest (see layers/attention.decode_attention)
    return {"k": z((batch, hkv, cache_cap, hd)),
            "v": z((batch, hkv, cache_cap, hd)),
            "ek": z((batch, hkv, enc_len, hd)),
            "ev": z((batch, hkv, enc_len, hd))}
