"""Configurable decoder-only LM covering the assigned families:

  dense GQA  (deepseek-coder-33b, llama3-405b, yi-6b, pixtral-12b backbone)
  MLA        (minicpm3-4b, deepseek-v2-236b)
  MoE        (deepseek-v2-236b, llama4-scout-17b-a16e)
  hybrid     (hymba-1.5b: parallel sliding-window attention + mamba heads)
  ssm        (rwkv6-7b: attention-free)

All layer stacks are lax.scan over stacked parameters (one compiled layer
body regardless of depth). Three entry points: forward/loss (training),
prefill (build caches + last-token logits), decode_step (one token).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.adapters import dense
from repro.layers.attention import (blocked_attention, decode_attention,
                                    masked_cache_write,
                                    paged_decode_attention)
from repro.layers.mla import (MLAConfig, init_mla_params, mla_attention,
                              mla_decode)
from repro.layers.mlp import swiglu
from repro.layers.moe import MoEConfig, moe_block
from repro.layers.norms import rms_norm
from repro.layers.rope import apply_rope
from repro.layers.rwkv import (RWKVConfig, init_rwkv_layer, rwkv_channel_mix,
                               rwkv_time_mix)
from repro.layers.ssm import SSMConfig, init_ssm_params, ssm_mix
from repro.sharding.rules import shard, shard_cache

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "lm"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 32
    d_ff: int = 256
    vocab: int = 256
    attn_type: str = "gqa"        # gqa | mla | none (rwkv)
    block_type: str = "dense"     # dense | moe | hybrid | rwkv
    window: int | None = None     # sliding-window size (hybrid)
    rope_theta: float = 10000.0
    input_mode: str = "tokens"    # tokens | embeddings (modality stubs)
    # MLA dims
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    moe_d_ff: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_seq_chunk: int = 512
    # SSM / hybrid
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_dt_rank: int = 32
    ssm_conv: int = 4
    # RWKV
    rwkv_head_size: int = 64
    rwkv_decay_rank: int = 64
    # execution
    attn_chunk: int = 512
    time_chunk: int = 512
    param_dtype: str = "float32"
    remat: bool = True

    # ---- derived sub-configs -------------------------------------------
    def mla(self) -> MLAConfig:
        return MLAConfig(d_model=self.d_model, n_heads=self.n_heads,
                         q_lora_rank=self.q_lora_rank,
                         kv_lora_rank=self.kv_lora_rank,
                         qk_nope_dim=self.qk_nope_dim,
                         qk_rope_dim=self.qk_rope_dim,
                         v_head_dim=self.v_head_dim,
                         rope_theta=self.rope_theta)

    def moe(self) -> MoEConfig:
        return MoEConfig(n_experts=self.n_experts, top_k=self.top_k,
                         d_model=self.d_model, d_ff=self.moe_d_ff,
                         n_shared=self.n_shared, shared_d_ff=self.shared_d_ff,
                         capacity_factor=self.capacity_factor,
                         seq_chunk=self.moe_seq_chunk)

    def ssm(self) -> SSMConfig:
        return SSMConfig(d_model=self.d_model,
                         d_inner=self.ssm_expand * self.d_model,
                         state=self.ssm_state, dt_rank=self.ssm_dt_rank,
                         conv=self.ssm_conv, time_chunk=self.time_chunk)

    def rwkv(self) -> RWKVConfig:
        return RWKVConfig(d_model=self.d_model,
                          head_size=self.rwkv_head_size,
                          decay_rank=self.rwkv_decay_rank, d_ff=self.d_ff,
                          time_chunk=min(self.time_chunk, 64))

    @property
    def param_count_estimate(self) -> int:
        specs = param_specs(self)
        import numpy as np
        return sum(int(np.prod(l.shape)) for l in
                   jax.tree.leaves(specs))


# ---------------------------------------------------------------------------
# Initialization.
# ---------------------------------------------------------------------------

def _uinit(key, shape, fan_in, dtype):
    return jax.random.uniform(key, shape, dtype, -1, 1) / math.sqrt(fan_in)


def _init_layer(cfg: ModelConfig, key: Array) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    p: dict[str, Any] = {"ln1_scale": jnp.ones((d,), dtype),
                         "ln2_scale": jnp.ones((d,), dtype)}
    ks = iter(jax.random.split(key, 24))
    if cfg.block_type == "rwkv":
        p.update(init_rwkv_layer(next(ks), cfg.rwkv(), dtype))
        return p
    # attention
    if cfg.attn_type == "gqa":
        hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        p["wq"] = _uinit(next(ks), (d, hq * hd), d, dtype)
        p["wk"] = _uinit(next(ks), (d, hkv * hd), d, dtype)
        p["wv"] = _uinit(next(ks), (d, hkv * hd), d, dtype)
        p["wo"] = _uinit(next(ks), (hq * hd, d), hq * hd, dtype)
    elif cfg.attn_type == "mla":
        p.update(init_mla_params(next(ks), cfg.mla(), dtype))
    if cfg.block_type == "hybrid":
        p.update(init_ssm_params(next(ks), cfg.ssm(), dtype))
    # ffn
    if cfg.block_type == "moe":
        mcfg = cfg.moe()
        p["w_router"] = _uinit(next(ks), (d, cfg.n_experts), d, dtype)
        p["we_gate"] = _uinit(next(ks), (cfg.n_experts, d, cfg.moe_d_ff), d,
                              dtype)
        p["we_up"] = _uinit(next(ks), (cfg.n_experts, d, cfg.moe_d_ff), d,
                            dtype)
        p["we_down"] = _uinit(next(ks), (cfg.n_experts, cfg.moe_d_ff, d),
                              cfg.moe_d_ff, dtype)
        if cfg.n_shared:
            sf = mcfg.shared_ff
            p["w_shared_gate"] = _uinit(next(ks), (d, sf), d, dtype)
            p["w_shared_up"] = _uinit(next(ks), (d, sf), d, dtype)
            p["w_shared_down"] = _uinit(next(ks), (sf, d), sf, dtype)
    else:
        p["w_gate"] = _uinit(next(ks), (d, cfg.d_ff), d, dtype)
        p["w_up"] = _uinit(next(ks), (d, cfg.d_ff), d, dtype)
        p["w_down"] = _uinit(next(ks), (cfg.d_ff, d), cfg.d_ff, dtype)
    return p


def init_params(cfg: ModelConfig, key: Array) -> PyTree:
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_head, k_layers = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(cfg, k))(layer_keys)
    params = {
        "layers": layers,
        "final_norm_scale": jnp.ones((cfg.d_model,), dtype),
        "lm_head": _uinit(k_head, (cfg.d_model, cfg.vocab), cfg.d_model,
                          dtype),
    }
    if cfg.input_mode == "tokens":
        params["embed"] = (jax.random.normal(k_emb, (cfg.vocab, cfg.d_model),
                                             dtype) * 0.02)
    return params


def param_specs(cfg: ModelConfig) -> PyTree:
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Layer bodies.
# ---------------------------------------------------------------------------

def _gqa_project(x, p, cfg, positions):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(x, p["wq"], p.get("wq_lora_a"), p.get("wq_lora_b"))
    k = dense(x, p["wk"], p.get("wk_lora_a"), p.get("wk_lora_b"))
    v = dense(x, p["wv"], p.get("wv_lora_a"), p.get("wv_lora_b"))
    q = apply_rope(q.reshape(b, s, hq, hd), positions, cfg.rope_theta)
    k = apply_rope(k.reshape(b, s, hkv, hd), positions, cfg.rope_theta)
    v = v.reshape(b, s, hkv, hd)
    return shard(q, "act_bthd"), shard(k, "act_bthd"), shard(v, "act_bthd")


def _attn_out(o, p, cfg):
    b, s = o.shape[:2]
    o = o.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return dense(o, p["wo"], p.get("wo_lora_a"), p.get("wo_lora_b"))


def _gqa_train(x, p, cfg: ModelConfig, positions):
    q, k, v = _gqa_project(x, p, cfg, positions)
    o = blocked_attention(q, k, v, chunk=cfg.attn_chunk, causal=True,
                          window=cfg.window)
    return _attn_out(o, p, cfg), (k, v)


def _gqa_decode(x, p, cfg: ModelConfig, cache, pos, active=None):
    """x: (B,1,d); cache: {"k": (B,Hkv,Smax,hd), "v": ...} (head-major).
    pos: () shared position, or (B,) per-row positions (pooled slot cache,
    repro.serve). active: optional (B,) bool — rows that are False leave
    their cache row untouched (masked per-row decode, multi-token blocks)."""
    positions = pos[None] if jnp.ndim(pos) == 0 else pos[:, None]
    q, k, v = _gqa_project(x, p, cfg, positions)
    k = k.transpose(0, 2, 1, 3)                 # (B, Hkv, 1, hd)
    v = v.transpose(0, 2, 1, 3)
    slot = jnp.mod(pos, cache["k"].shape[2]) if cfg.window is not None \
        else pos
    k_cache = shard(masked_cache_write(cache["k"], k, slot, axis=2,
                                       active=active), "decode_kv")
    v_cache = shard(masked_cache_write(cache["v"], v, slot, axis=2,
                                       active=active), "decode_kv")
    o = decode_attention(q, k_cache, v_cache, pos + 1,
                         ring=cfg.window is not None)
    return _attn_out(o, p, cfg), {"k": k_cache, "v": v_cache}


def _ffn(x, p, cfg: ModelConfig):
    if cfg.block_type == "moe":
        return moe_block(x, p, cfg.moe())
    return swiglu(x, p)


def _layer_train(cfg: ModelConfig, x, p, positions):
    if cfg.block_type == "rwkv":
        a, _ = rwkv_time_mix(rms_norm(x, p["ln1_scale"]), p, cfg.rwkv())
        x = x + a
        f, _ = rwkv_channel_mix(rms_norm(x, p["ln2_scale"]), p)
        return x + f
    h = rms_norm(x, p["ln1_scale"])
    if cfg.attn_type == "mla":
        a, _ = mla_attention(h, p, cfg.mla(), positions, chunk=cfg.attn_chunk)
    else:
        a, _ = _gqa_train(h, p, cfg, positions)
    if cfg.block_type == "hybrid":
        s_out, _ = ssm_mix(h, p, cfg.ssm())
        a = (a + s_out) * 0.5
    x = x + a
    h2 = rms_norm(x, p["ln2_scale"])
    return x + _ffn(h2, p, cfg)


def _layer_prefill(cfg: ModelConfig, x, p, positions, cache_cap: int):
    """Returns (x, layer_cache). Caches are sized `cache_cap` (>= S)."""
    b, s, _ = x.shape
    dtype = x.dtype
    if cfg.block_type == "rwkv":
        h = rms_norm(x, p["ln1_scale"])
        a, st = rwkv_time_mix(h, p, cfg.rwkv())
        x = x + a
        h2 = rms_norm(x, p["ln2_scale"])
        f, x_ffn = rwkv_channel_mix(h2, p)
        x = x + f
        return x, {"x_att": st["x_att"], "s": st["s"], "x_ffn": x_ffn}
    h = rms_norm(x, p["ln1_scale"])
    cache: dict[str, Array] = {}
    if cfg.attn_type == "mla":
        a, kv = mla_attention(h, p, cfg.mla(), positions, chunk=cfg.attn_chunk)
        pad = cache_cap - s
        cache["ckv"] = jnp.pad(kv["ckv"], ((0, 0), (0, pad), (0, 0)))
        cache["kpe"] = jnp.pad(kv["kpe"], ((0, 0), (0, pad), (0, 0)))
    else:
        a, (k, v) = _gqa_train(h, p, cfg, positions)
        if cfg.window is not None:
            w = min(cfg.window, cache_cap)
            # ring layout: entry for position p sits at slot p % w
            kw, vw = k[:, -w:], v[:, -w:]
            if s >= w:
                # slot of position p is p % w; kw[j] holds position s - w + j
                roll = (s - w) % w
                kw = jnp.roll(kw, roll, axis=1)
                vw = jnp.roll(vw, roll, axis=1)
            else:
                kw = jnp.pad(kw, ((0, 0), (0, w - s), (0, 0), (0, 0)))
                vw = jnp.pad(vw, ((0, 0), (0, w - s), (0, 0), (0, 0)))
            cache["k"] = kw.astype(dtype).transpose(0, 2, 1, 3)
            cache["v"] = vw.astype(dtype).transpose(0, 2, 1, 3)
        else:
            pad = cache_cap - s
            cache["k"] = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))
                                 ).transpose(0, 2, 1, 3)
            cache["v"] = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))
                                 ).transpose(0, 2, 1, 3)
    if cfg.block_type == "hybrid":
        s_out, st = ssm_mix(h, p, cfg.ssm())
        a = (a + s_out) * 0.5
        cache["conv"] = st["conv"]
        cache["h"] = st["h"]
    x = x + a
    h2 = rms_norm(x, p["ln2_scale"])
    return x + _ffn(h2, p, cfg), cache


def _keep_inactive(active, new, old):
    """Per-row state merge for recurrent caches (ssm/rwkv) that have no
    positional write to mask: inactive rows keep their old state."""
    mask = active.reshape((-1,) + (1,) * (new.ndim - 1))
    return jnp.where(mask, new, old.astype(new.dtype))


def _layer_decode(cfg: ModelConfig, x, p, cache, pos, active=None):
    if cfg.block_type == "rwkv":
        h = rms_norm(x, p["ln1_scale"])
        a, st = rwkv_time_mix(h, p, cfg.rwkv(),
                              state={"x_att": cache["x_att"],
                                     "s": cache["s"]})
        x = x + a
        h2 = rms_norm(x, p["ln2_scale"])
        f, x_ffn = rwkv_channel_mix(h2, p, state=cache["x_ffn"])
        x = x + f
        new_cache = {"x_att": st["x_att"], "s": st["s"], "x_ffn": x_ffn}
        if active is not None:
            new_cache = jax.tree.map(
                functools.partial(_keep_inactive, active), new_cache, cache)
        return x, new_cache
    h = rms_norm(x, p["ln1_scale"])
    new_cache = dict(cache)
    if cfg.attn_type == "mla":
        assert active is None, "masked per-row decode needs GQA"
        a, kv = mla_decode(h, p, cfg.mla(),
                           {"ckv": cache["ckv"], "kpe": cache["kpe"]}, pos)
        new_cache.update(kv)
    else:
        a, kv = _gqa_decode(h, p, cfg, cache, pos, active=active)
        new_cache.update(kv)
    if cfg.block_type == "hybrid":
        s_out, st = ssm_mix(h, p, cfg.ssm(),
                            state={"conv": cache["conv"], "h": cache["h"]})
        a = (a + s_out) * 0.5
        new_conv, new_h = st["conv"], st["h"]
        if active is not None:
            new_conv = _keep_inactive(active, new_conv, cache["conv"])
            new_h = _keep_inactive(active, new_h, cache["h"])
        new_cache["conv"] = new_conv
        new_cache["h"] = new_h
    x = x + a
    h2 = rms_norm(x, p["ln2_scale"])
    return x + _ffn(h2, p, cfg), new_cache


# ---------------------------------------------------------------------------
# Full-model entry points.
# ---------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params, inputs) -> Array:
    if cfg.input_mode == "embeddings":
        x = inputs.astype(jnp.dtype(cfg.param_dtype))
    else:
        x = jnp.take(params["embed"], inputs, axis=0)
    return shard(x, "act_btd")


def forward(cfg: ModelConfig, params: PyTree, inputs: Array) -> Array:
    """Training forward -> logits (B, S, vocab)."""
    x = _embed(cfg, params, inputs)
    positions = jnp.arange(x.shape[1])

    def body(h, lp):
        h = _layer_train(cfg, h, lp, positions)
        return shard(h, "act_btd"), None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm_scale"])
    logits = dense(x, params["lm_head"])
    return shard(logits, "logits")


def loss_fn(cfg: ModelConfig, params: PyTree, batch: dict
            ) -> tuple[Array, dict]:
    """batch: {"inputs": tokens or embeddings, "targets": (B,S) int32 with
    -1 = masked}."""
    logits = forward(cfg, params, batch["inputs"])
    targets = batch["targets"]
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    picked = jnp.sum(logits32 * jax.nn.one_hot(tgt, cfg.vocab,
                                               dtype=jnp.float32), axis=-1)
    nll = (lse - picked) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    return loss, {"loss": loss, "tokens": denom}


def prefill(cfg: ModelConfig, params: PyTree, inputs: Array,
            cache_cap: int) -> tuple[Array, PyTree]:
    """Run the full prompt; returns (last-token logits (B, vocab), cache).
    Full-seq logits are deliberately never materialized."""
    x = _embed(cfg, params, inputs)
    positions = jnp.arange(x.shape[1])

    def body(h, lp):
        h, lc = _layer_prefill(cfg, h, lp, positions, cache_cap)
        return shard(h, "act_btd"), lc

    x, cache = jax.lax.scan(body, x, params["layers"])
    # Pin the stacked cache to its canonical layout (cache_pspecs) before it
    # leaves the jit: the serving engine scatters prefill group caches into a
    # pooled slot cache placed with exactly this sharding, so the scatter is
    # a local per-shard write instead of a reshard (identity off-mesh).
    cache = shard_cache(cache)
    x_last = rms_norm(x[:, -1:], params["final_norm_scale"])
    logits = shard(dense(x_last, params["lm_head"])[:, 0], "decode_logits")
    return logits, cache


def decode_step(cfg: ModelConfig, params: PyTree, cache: PyTree,
                tokens: Array, pos: Array,
                active: Array | None = None) -> tuple[Array, PyTree]:
    """tokens: (B,) int32 (or (B, d) embeddings); pos: () current index,
    or (B,) per-row indices (continuous batching — GQA/hybrid/RWKV only;
    MLA decode keeps a shared position). active: optional (B,) bool mask —
    inactive rows still flow through the batch (SPMD) but leave every cache
    row bit-identical, so finished/empty serving slots can ride inside a
    fused multi-token decode block (repro.serve). Adapter leaves in
    `params` may be GroupedAdapter wrappers (per-slot fp32 or rows-coded
    stacks): the layer scan unstacks their parts like any leaf, and
    core.adapters.dense dispatches them to the grouped fused
    (dequant-and-)apply (train.steps stages coded non-Pallas wrappers
    once per decode block before calling in here). Returns (logits
    (B, vocab), updated cache)."""
    if jnp.ndim(pos) == 1 or active is not None:
        assert cfg.attn_type != "mla", "per-row decode positions need GQA"
    if cfg.input_mode == "embeddings":
        x = tokens[:, None, :].astype(jnp.dtype(cfg.param_dtype))
    else:
        x = jnp.take(params["embed"], tokens[:, None], axis=0)

    # The cache rides in the scan CARRY (updated in place with per-layer
    # dynamic slices) instead of xs->ys: a ys-stacked cache output is a
    # second full-cache buffer and doubles decode peak memory (observed at
    # +8.5 GB/device on the 405B dry-run). shard_cache pins the carry's
    # sharding — GSPMD otherwise replicates loop state.
    n_layers = cfg.n_layers
    cache = shard_cache(cache)

    def body(carry, inp):
        h, full_cache = carry
        lp, idx = inp
        lc = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0,
                                                   keepdims=False),
            full_cache)
        h, new_lc = _layer_decode(cfg, h, lp, lc, pos, active=active)
        full_cache = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), idx, 0),
            full_cache, new_lc)
        return (h, shard_cache(full_cache)), None

    (x, new_cache), _ = jax.lax.scan(
        body, (x, cache), (params["layers"], jnp.arange(n_layers)))
    x = rms_norm(x[:, -1:], params["final_norm_scale"])
    # vocab tiled on model straight out of the lm_head matmul: greedy argmax
    # in the fused serve decode block reduces shard-locally (identity off-mesh)
    logits = shard(dense(x, params["lm_head"])[:, 0], "decode_logits")
    return logits, new_cache


# ---------------------------------------------------------------------------
# Paged KV cache (repro.serve paged engine): fixed-size pages + page tables.
# ---------------------------------------------------------------------------

def _require_paged_support(cfg: ModelConfig):
    """Paged decode needs positional KV writes into a flat page pool: GQA
    attention, dense blocks, no sliding-window ring buffer. (MLA/hybrid/RWKV
    carry latent or recurrent state the page layout has no slot for — serve
    those with the dense pooled cache.)"""
    if (cfg.attn_type != "gqa" or cfg.block_type != "dense"
            or cfg.window is not None):
        raise ValueError(
            "paged KV cache supports dense GQA models without sliding "
            f"window (got attn={cfg.attn_type!r} block={cfg.block_type!r} "
            f"window={cfg.window!r})")


def paged_cache_supported(cfg: ModelConfig) -> bool:
    """True when the config can serve from a paged KV pool (see
    _require_paged_support) — the engine's auto mode falls back to the
    dense pooled cache otherwise."""
    try:
        _require_paged_support(cfg)
        return True
    except ValueError:
        return False


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                     dtype=jnp.bfloat16) -> PyTree:
    """Paged KV pool: {"k_pages","v_pages"}: (L, n_pages, Hkv, page_size,
    hd). Page 0 is the engine's null page (never allocated to a slot;
    masked writes land there). Logical page p of a slot holds that slot's
    global positions [p*page_size, (p+1)*page_size) — the page table maps
    logical to physical."""
    _require_paged_support(cfg)
    shape = (cfg.n_layers, n_pages, cfg.n_kv_heads, page_size, cfg.head_dim)
    return {"k_pages": jnp.zeros(shape, dtype),
            "v_pages": jnp.zeros(shape, dtype)}


def _paged_cache_write(lc: dict, k: Array, v: Array, page_table: Array,
                       pos: Array, active: Array | None) -> dict:
    """Scatter one token's K/V per row into the layer's page pool. k/v:
    (B, Hkv, 1, hd) head-major; pos: (B,) write positions. Inactive rows
    are pointed at the null page 0 — their real pages stay bit-identical
    (the paged analog of masked_cache_write's active= contract)."""
    ps = lc["k_pages"].shape[2]
    off = jnp.mod(pos, ps)
    phys = jnp.take_along_axis(page_table, (pos // ps)[:, None], axis=1)[:, 0]
    if active is not None:
        phys = jnp.where(active, phys, 0)
    kc = lc["k_pages"].at[phys, :, off].set(
        k[:, :, 0].astype(lc["k_pages"].dtype))
    vc = lc["v_pages"].at[phys, :, off].set(
        v[:, :, 0].astype(lc["v_pages"].dtype))
    return {"k_pages": kc, "v_pages": vc}


def _gqa_decode_paged(x, p, cfg: ModelConfig, lc, page_table, pos,
                      active=None, *, num_active_pages: int,
                      use_pallas=False, interpret=False):
    """x: (B,1,d); lc: one layer's {"k_pages","v_pages"} page-pool slice.
    pos: (B,) per-row positions (always vectors — the paged cache only
    exists for the pooled continuous-batching engine). The attention read
    covers only page_table[:, :num_active_pages] (static slice)."""
    q, k, v = _gqa_project(x, p, cfg, pos[:, None])
    k = k.transpose(0, 2, 1, 3)                     # (B, Hkv, 1, hd)
    v = v.transpose(0, 2, 1, 3)
    new_lc = _paged_cache_write(lc, k, v, page_table, pos, active)
    o = paged_decode_attention(q, new_lc["k_pages"], new_lc["v_pages"],
                               page_table[:, :num_active_pages], pos + 1,
                               use_pallas=use_pallas, interpret=interpret)
    return _attn_out(o, p, cfg), new_lc


def _layer_decode_paged(cfg: ModelConfig, x, p, lc, page_table, pos,
                        active, num_active_pages, use_pallas, interpret):
    h = rms_norm(x, p["ln1_scale"])
    a, new_lc = _gqa_decode_paged(h, p, cfg, lc, page_table, pos, active,
                                  num_active_pages=num_active_pages,
                                  use_pallas=use_pallas, interpret=interpret)
    x = x + a
    h2 = rms_norm(x, p["ln2_scale"])
    return x + _ffn(h2, p, cfg), new_lc


def decode_step_paged(cfg: ModelConfig, params: PyTree, pool: PyTree,
                      page_table: Array, tokens: Array, pos: Array,
                      active: Array | None = None, *,
                      num_active_pages: int, use_pallas: bool = False,
                      interpret: bool = False) -> tuple[Array, PyTree]:
    """One decode token per row against the PAGED pool. pool:
    init_paged_cache layout; page_table: (B, max_pages_per_slot) int32;
    tokens/pos: (B,); active: optional (B,) mask (inactive rows write only
    the null page and keep their counters — same contract as decode_step).
    num_active_pages (static) bounds the attention read to the pages any
    row can actually occupy this step — decode FLOPs and bytes scale with
    live pages, not pool capacity. Returns (logits (B, vocab), pool)."""
    _require_paged_support(cfg)
    x = jnp.take(params["embed"], tokens[:, None], axis=0)
    pool = shard_cache(pool)
    page_table = shard(page_table, "serve_page_table")

    def body(carry, inp):
        h, full = carry
        lp, idx = inp
        lc = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0,
                                                   keepdims=False), full)
        h, new_lc = _layer_decode_paged(cfg, h, lp, lc, page_table, pos,
                                        active, num_active_pages,
                                        use_pallas, interpret)
        full = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), idx, 0), full, new_lc)
        return (h, shard_cache(full)), None

    (x, new_pool), _ = jax.lax.scan(
        body, (x, pool), (params["layers"], jnp.arange(cfg.n_layers)))
    x = rms_norm(x[:, -1:], params["final_norm_scale"])
    logits = shard(dense(x, params["lm_head"])[:, 0], "decode_logits")
    return logits, new_pool


def _layer_chunk_prefill(cfg: ModelConfig, x, p, lc, page_row, positions,
                         num_pages: int, use_pallas, interpret):
    """One layer of chunked prefill: project the chunk, scatter its K/V
    into the slot's pages, then causally attend over ALL the slot's live
    pages (earlier chunks included). x: (1, Sc, d); page_row: (max_pages,)
    physical ids for the one slot being chunk-prefilled."""
    h = rms_norm(x, p["ln1_scale"])
    q, k, v = _gqa_project(h, p, cfg, positions)
    ps = lc["k_pages"].shape[2]
    phys = page_row[positions // ps]                     # (Sc,)
    off = jnp.mod(positions, ps)
    kc = lc["k_pages"].at[phys, :, off].set(
        k[0].astype(lc["k_pages"].dtype))                # k[0]: (Sc,Hkv,hd)
    vc = lc["v_pages"].at[phys, :, off].set(
        v[0].astype(lc["v_pages"].dtype))
    # gather the slot's first num_pages pages and linearize: (1,Hkv,K,hd)
    k_lin = kc[page_row[:num_pages]].transpose(1, 0, 2, 3).reshape(
        1, kc.shape[1], num_pages * ps, kc.shape[3])
    v_lin = vc[page_row[:num_pages]].transpose(1, 0, 2, 3).reshape(
        1, vc.shape[1], num_pages * ps, vc.shape[3])
    b, sc_len, hq, dh = q.shape
    hkv = kc.shape[1]
    qg = q.reshape(b, sc_len, hkv, hq // hkv, dh)
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bshgd,bhkd->bshgk", qg.astype(k_lin.dtype), k_lin,
                        preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(num_pages * ps)                    # linearized positions
    valid = kpos[None, :] <= positions[:, None]          # causal over prefix
    scores = jnp.where(valid[None, :, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bshgk,bhkd->bshgd", probs.astype(v_lin.dtype), v_lin,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, sc_len, hq, dh).astype(q.dtype)
    a = _attn_out(o, p, cfg)
    x = x + a
    h2 = rms_norm(x, p["ln2_scale"])
    return x + _ffn(h2, p, cfg), {"k_pages": kc, "v_pages": vc}


def prefill_chunk(cfg: ModelConfig, params: PyTree, pool: PyTree,
                  page_table: Array, tokens: Array, start: Array, *,
                  num_pages: int, use_pallas: bool = False,
                  interpret: bool = False) -> tuple[Array, PyTree]:
    """Chunked prefill: run `tokens` (1, Sc) — one piece of one long prompt
    — at positions [start, start + Sc), writing their K/V into the slot's
    pages and attending causally over everything the slot has cached so
    far. num_pages (static) = pages covering start + Sc. Returns
    (last-token logits (1, vocab), pool); the engine uses the logits only
    on the final chunk (they ARE the request's first generated token).
    Earlier chunks' K/V land via the page table exactly where full prefill
    would scatter them, so decode after the last chunk is oblivious to how
    the prompt entered the cache."""
    _require_paged_support(cfg)
    x = _embed(cfg, params, tokens)
    positions = start + jnp.arange(tokens.shape[1])
    pool = shard_cache(pool)
    page_row = shard(page_table, "serve_page_table")[0]

    def body(carry, inp):
        h, full = carry
        lp, idx = inp
        lc = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0,
                                                   keepdims=False), full)
        h, new_lc = _layer_chunk_prefill(cfg, h, lp, lc, page_row,
                                         positions, num_pages,
                                         use_pallas, interpret)
        full = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), idx, 0), full, new_lc)
        return (h, shard_cache(full)), None

    (x, new_pool), _ = jax.lax.scan(
        body, (x, pool), (params["layers"], jnp.arange(cfg.n_layers)))
    x = rms_norm(x[:, -1:], params["final_norm_scale"])
    logits = shard(dense(x, params["lm_head"])[:, 0], "decode_logits")
    return logits, new_pool


def init_cache(cfg: ModelConfig, batch: int, cache_cap: int,
               dtype=jnp.bfloat16) -> PyTree:
    """Abstract-friendly cache allocation (used via jax.eval_shape for the
    dry-run and concretely for serving)."""
    l = cfg.n_layers

    def zeros(shape, dt=dtype):
        return jnp.zeros((l,) + shape, dt)

    if cfg.block_type == "rwkv":
        rc = cfg.rwkv()
        return {"x_att": zeros((batch, cfg.d_model)),
                "x_ffn": zeros((batch, cfg.d_model)),
                "s": zeros((batch, rc.n_heads, rc.head_size, rc.head_size),
                           jnp.float32)}
    cache: dict[str, Array] = {}
    if cfg.attn_type == "mla":
        cache["ckv"] = zeros((batch, cache_cap, cfg.kv_lora_rank))
        cache["kpe"] = zeros((batch, cache_cap, cfg.qk_rope_dim))
    else:
        cap = min(cfg.window, cache_cap) if cfg.window else cache_cap
        # head-major at rest: (B, Hkv, S, hd) — see decode_attention
        cache["k"] = zeros((batch, cfg.n_kv_heads, cap, cfg.head_dim))
        cache["v"] = zeros((batch, cfg.n_kv_heads, cap, cfg.head_dim))
    if cfg.block_type == "hybrid":
        sc = cfg.ssm()
        cache["conv"] = zeros((batch, sc.conv - 1, sc.d_inner))
        cache["h"] = zeros((batch, sc.d_inner, sc.state), jnp.float32)
    return cache
