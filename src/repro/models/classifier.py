"""The paper's own from-scratch compression testbeds: an MLP classifier
(MNIST ablations, S4.3/A.4: two hidden layers of 256) and a mini ViT
(Table 1 family). Both are compressed with *direct-mode* MCNC (chunks over
the raw weights, theta_0 = seed-reconstructable random init)."""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.adapters import dense
from repro.layers.attention import blocked_attention
from repro.layers.norms import layer_norm

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# MLP (paper A.4: 784 -> 256 -> 256 -> 10 for MNIST).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLPConfig:
    in_dim: int = 784
    hidden: int = 256
    n_hidden: int = 2
    classes: int = 10


def mlp_init(cfg: MLPConfig, key: Array) -> PyTree:
    """Nested-dict params ('fc0': {'w','b'}) so the MCNC flatten/unflatten
    path roundtrips them."""
    dims = [cfg.in_dim] + [cfg.hidden] * cfg.n_hidden + [cfg.classes]
    params = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        params[f"fc{i}"] = {
            "w": (jax.random.normal(sub, (a, b), jnp.float32)
                  * math.sqrt(2.0 / a)),
            "b": jnp.zeros((b,), jnp.float32),
        }
    return params


def mlp_forward(cfg: MLPConfig, params: PyTree, x: Array) -> Array:
    n = cfg.n_hidden + 1
    h = x
    for i in range(n):
        h = h @ params[f"fc{i}"]["w"] + params[f"fc{i}"]["b"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


# ---------------------------------------------------------------------------
# Mini ViT (Table 1 family: ViT-Ti/S shapes, patchified image input).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ViTConfig:
    name: str = "vit_ti"
    image: int = 32
    patch: int = 4
    d_model: int = 192          # ViT-Ti: 192, ViT-S: 384
    n_layers: int = 12
    n_heads: int = 3            # ViT-Ti: 3, ViT-S: 6
    d_ff: int = 768
    classes: int = 100

    @property
    def n_patches(self) -> int:
        return (self.image // self.patch) ** 2


# Paper configs (ImageNet-100 tables use 224/16; we default to CIFAR-scale
# for runnable examples and keep the full shapes available).
VIT_TI = ViTConfig(name="vit_ti", image=224, patch=16, d_model=192,
                   n_layers=12, n_heads=3, d_ff=768, classes=100)
VIT_S = ViTConfig(name="vit_s", image=224, patch=16, d_model=384,
                  n_layers=12, n_heads=6, d_ff=1536, classes=100)


def vit_init(cfg: ViTConfig, key: Array) -> PyTree:
    d = cfg.d_model
    pdim = 3 * cfg.patch * cfg.patch
    ks = iter(jax.random.split(key, 8 + 8 * cfg.n_layers))

    def lin(k, a, b):
        return jax.random.normal(k, (a, b), jnp.float32) * math.sqrt(1.0 / a)

    params: dict[str, Any] = {
        "patch_embed": {"w": lin(next(ks), pdim, d)},
        "pos_emb": jax.random.normal(next(ks),
                                     (cfg.n_patches + 1, d)) * 0.02,
        "cls_token": jnp.zeros((d,), jnp.float32),
        "head": {"w": lin(next(ks), d, cfg.classes),
                 "b": jnp.zeros((cfg.classes,), jnp.float32)},
        "final_ln": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
    }

    def layer(k):
        kk = iter(jax.random.split(k, 8))
        return {
            "ln1": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
            "ln2": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
            "wq": lin(next(kk), d, d), "wk": lin(next(kk), d, d),
            "wv": lin(next(kk), d, d), "wo": lin(next(kk), d, d),
            "w_fc1": lin(next(kk), d, cfg.d_ff),
            "w_fc2": lin(next(kk), cfg.d_ff, d),
        }

    layer_keys = jax.random.split(next(ks), cfg.n_layers)
    params["layers"] = jax.vmap(layer)(layer_keys)
    return params


def vit_forward(cfg: ViTConfig, params: PyTree, images: Array) -> Array:
    """images: (B, H, W, 3) -> logits (B, classes)."""
    b = images.shape[0]
    p, d = cfg.patch, cfg.d_model
    hp = cfg.image // p
    x = images.reshape(b, hp, p, hp, p, 3).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(b, hp * hp, p * p * 3)
    x = x @ params["patch_embed"]["w"]
    cls = jnp.broadcast_to(params["cls_token"], (b, 1, d))
    x = jnp.concatenate([cls, x], axis=1) + params["pos_emb"][None]
    hd = d // cfg.n_heads

    def body(h, lp):
        hh = layer_norm(h, lp["ln1"]["scale"], lp["ln1"]["bias"])
        q = dense(hh, lp["wq"]).reshape(b, -1, cfg.n_heads, hd)
        k = dense(hh, lp["wk"]).reshape(b, -1, cfg.n_heads, hd)
        v = dense(hh, lp["wv"]).reshape(b, -1, cfg.n_heads, hd)
        a = blocked_attention(q, k, v, chunk=256, causal=False)
        h = h + dense(a.reshape(b, -1, d), lp["wo"])
        h2 = layer_norm(h, lp["ln2"]["scale"], lp["ln2"]["bias"])
        h = h + dense(jax.nn.gelu(dense(h2, lp["w_fc1"])), lp["w_fc2"])
        return h, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    x = layer_norm(x, params["final_ln"]["scale"],
                   params["final_ln"]["bias"])
    return x[:, 0] @ params["head"]["w"] + params["head"]["b"]


def xent_loss(logits: Array, labels: Array) -> Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def accuracy(logits: Array, labels: Array) -> Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels)
                    .astype(jnp.float32))
