"""Deterministic synthetic data pipelines (no datasets ship in this
container; see README.md §Benchmarks faithfulness notes).

Design points that matter at cluster scale and are preserved here:
  * shard-aware: each data-parallel rank derives its slice of the global
    batch from (seed, step, rank) — no coordination, identical on restart;
  * stateless/resumable: batch(step) is a pure function, so checkpoint
    restore at step k regenerates exactly the batch stream from k;
  * structured targets: the LM stream is a noisy Markov chain (learnable
    structure — loss decreases), the classifier stream is a fixed random
    teacher (accuracy is meaningful).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LMStreamConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    order: int = 1            # Markov order of the synthetic language
    noise: float = 0.1        # fraction of uniform-random tokens


def _markov_table(cfg: LMStreamConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed)
    # Sparse-ish transition table: each token prefers a few successors.
    table = rng.dirichlet(np.full(min(cfg.vocab, 64), 0.3),
                          size=cfg.vocab)
    succ = rng.integers(0, cfg.vocab, size=(cfg.vocab, table.shape[1]))
    return succ, table


class LMStream:
    """Deterministic synthetic token stream with next-token structure."""

    def __init__(self, cfg: LMStreamConfig):
        self.cfg = cfg
        self.succ, self.table = _markov_table(cfg)

    def batch(self, step: int, rank: int = 0, world: int = 1) -> dict:
        cfg = self.cfg
        assert cfg.global_batch % world == 0
        local_b = cfg.global_batch // world
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + rank)
        toks = np.empty((local_b, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=local_b)
        for t in range(cfg.seq_len):
            prev = toks[:, t]
            choice = np.array([
                self.succ[p, rng.choice(self.table.shape[1],
                                        p=self.table[p])]
                for p in prev])
            noise = rng.random(local_b) < cfg.noise
            choice[noise] = rng.integers(0, cfg.vocab, size=noise.sum())
            toks[:, t + 1] = choice
        return {"inputs": jnp.asarray(toks[:, :-1]),
                "targets": jnp.asarray(toks[:, 1:])}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class TeacherStreamConfig:
    in_dim: int
    classes: int
    batch: int
    seed: int = 0
    teacher_hidden: int = 64
    label_noise: float = 0.0


class TeacherStream:
    """Classification data labeled by a fixed random 2-layer teacher —
    an MNIST stand-in with real learnable signal."""

    def __init__(self, cfg: TeacherStreamConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.w1 = rng.standard_normal((cfg.in_dim, cfg.teacher_hidden)) \
            / np.sqrt(cfg.in_dim)
        self.w2 = rng.standard_normal((cfg.teacher_hidden, cfg.classes)) \
            / np.sqrt(cfg.teacher_hidden)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed * 7_919 + step)
        x = rng.standard_normal((cfg.batch, cfg.in_dim)).astype(np.float32)
        logits = np.maximum(x @ self.w1, 0.0) @ self.w2
        y = logits.argmax(-1)
        if cfg.label_noise:
            flip = rng.random(cfg.batch) < cfg.label_noise
            y[flip] = rng.integers(0, cfg.classes, size=flip.sum())
        return {"x": jnp.asarray(x), "y": jnp.asarray(y.astype(np.int32))}


def host_prefetch(stream, start_step: int = 0, ahead: int = 2):
    """Tiny prefetch queue (thread) over a .batch(step) source."""
    import queue
    import threading
    q: "queue.Queue" = queue.Queue(maxsize=ahead)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            q.put((step, stream.batch(step)))
            step += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    def gen():
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
    return gen()
