"""Quickstart: compress a small LM's adapters with MCNC and fine-tune on a
synthetic stream — the paper's S4.2 regime end to end on CPU in ~a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.core.generator import GeneratorConfig, init_generator
from repro.data.pipeline import LMStream, LMStreamConfig
from repro.optim import AdamConfig, adam_init
from repro.train.steps import build_bundle, make_train_step


def main():
    arch = get_arch("yi_6b")                     # reduced config via smoke
    gen = GeneratorConfig(k=5, d=1000, width=32, seed=0)
    bundle = build_bundle(arch, "mcnc", smoke=True, generator=gen,
                          adapter_rank=4)
    print(f"model: {bundle.model_cfg.name}")
    print(f"compression: {bundle.plan.summary()['compression_rate']:.4%} "
          f"of the adapter set "
          f"({bundle.plan.trainable_params} trainable params)")

    base = bundle.init_base(jax.random.PRNGKey(0))
    trainable = bundle.init_trainable(jax.random.PRNGKey(1))
    gen_ws = init_generator(bundle.gen_cfg)
    opt = adam_init(trainable)
    # Paper Table 10: MCNC takes a 5-10x larger LR than uncompressed training.
    step = jax.jit(make_train_step(bundle, AdamConfig(lr=0.05)))

    data = LMStream(LMStreamConfig(vocab=bundle.model_cfg.vocab, seq_len=64,
                                   global_batch=8, seed=0))
    for i in range(30):
        batch = data.batch(i)
        trainable, opt, metrics = step(trainable, opt, base, gen_ws, batch,
                                       jnp.int32(i))
        if i % 5 == 0:
            print(f"step {i:3d}  loss {float(metrics['loss']):.4f}")
    print("done — loss should be falling; the only trained state was "
          f"{bundle.plan.trainable_params} (alpha, beta) scalars.")


if __name__ == "__main__":
    main()
