"""Batched multi-task adapter serving — the paper's Table-4 motivating
scenario: ONE frozen base model, MANY tasks' MCNC adapters, each a tiny
(seed, alpha, beta) bundle ("processing multiple tasks and their
corresponding adapters in a batch... MCNC holds an advantage over NOLA due
to its faster throughput").

This driver exercises the full serving stack (repro.serve):
  1. publish N task bundles into an on-disk AdapterRegistry (atomic,
     hash-verified artifacts — MBs per task, not GBs);
  2. spin up a ServeEngine: continuous-batching scheduler over a pooled
     slot KV cache + a byte-budgeted expansion cache;
  3. submit mixed-task traffic and drain it — prefills admit in task-pure
     groups, decodes run every active slot in ONE mixed multi-task batch
     with per-slot adapters;
  4. hot-swap one task's bundle mid-demo and serve from the new weights
     without restarting anything.

With --mesh DxM the SAME engine runs sharded over a (data, model) device
mesh (CPU-simulated host devices are requested automatically): frozen base
tensor-parallel, KV pool slots-over-data / sequence-over-model, expansion
output model-axis tiled — token-identical to the single-device run.

Bundles land on disk in wire format v2 (quantized + entropy-coded; spec in
docs/ARCHITECTURE.md): --quant int8 shrinks each task's artifact ~5x, and
--quantized-cache makes the engine hold the CODED bundles in its expansion
cache (LRU bytes charge the quantized arrays, not the expanded fp32
leaves) and dequantize inside the jitted expansion — same tokens,
orders-of-magnitude smaller cache entries.

    PYTHONPATH=src python examples/serve_adapters.py [--tasks 4] [--mesh 2x4]
        [--quant int8] [--quantized-cache]
"""
import argparse
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# --mesh must be seen BEFORE jax initializes its backends so XLA_FLAGS can
# request the CPU-simulated host devices (see launch.mesh helpers)
from repro.launch.mesh import ensure_host_device_flags, mesh_spec_from_argv

_MESH_SPEC = mesh_spec_from_argv(sys.argv)
if _MESH_SPEC:
    ensure_host_device_flags(_MESH_SPEC)

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.core.generator import GeneratorConfig, init_generator
from repro.serve import AdapterRegistry, ExpansionCache, ServeEngine
from repro.train.steps import build_bundle


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=4)
    ap.add_argument("--requests-per-task", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--n-slots", type=int, default=8)
    ap.add_argument("--horizon", type=int, default=8,
                    help="fused decode block length K (tokens per dispatch)")
    ap.add_argument("--mesh", default=None,
                    help="run the engine sharded over a DxM (data, model) "
                         "mesh of CPU-simulated devices, e.g. 2x4")
    ap.add_argument("--quant", default="int8",
                    choices=["none", "int8", "nf4"],
                    help="bundle quantization scheme for published "
                         "artifacts (wire format v2)")
    ap.add_argument("--quantized-cache", action="store_true",
                    help="hold CODED bundles in the expansion cache and "
                         "dequantize inside the jitted expansion")
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(args.mesh)
        print(f"mesh {args.mesh}: {len(jax.devices())} host devices, axes "
              f"{dict(zip(mesh.axis_names, mesh.devices.shape))} — base "
              "params tensor-parallel, KV pool slots/data + seq/model, "
              "adapter stacks slots/data, expansion output model-tiled")

    arch = get_arch("yi_6b")
    gen = GeneratorConfig(k=5, d=1000, width=32, seed=0)
    bundle = build_bundle(arch, "mcnc", smoke=True, generator=gen,
                          adapter_rank=4)
    cfg = bundle.model_cfg
    base = bundle.init_base(jax.random.PRNGKey(0))
    gen_ws = init_generator(gen)

    # N per-task adapter states (in real use these come from N fine-tunes;
    # here: distinct random alphas), published as registry bundles.
    registry = AdapterRegistry(tempfile.mkdtemp(prefix="adapters_"))
    for i in range(args.tasks):
        registry.publish(f"task{i}", bundle.synthetic_trainable(i), gen,
                         adapter={"rank": 4}, quant=args.quant)
    n_tp = bundle.plan.trainable_params
    task0_dir = os.path.join(registry.root, "task0")
    disk = sum(os.path.getsize(os.path.join(task0_dir, f))
               for f in os.listdir(task0_dir))
    print(f"{args.tasks} task adapters x {n_tp} trainable params each "
          f"(~{n_tp * 4 / 1024:.1f} KiB fp32 state; {disk / 1024:.1f} KiB "
          f"artifact on disk as v2/{args.quant} incl. manifest+header — "
          f"benchmarks/bundle_bench.py measures ratios at realistic state "
          f"sizes; vs {bundle.plan.represented_params * 2 / 1e6:.1f} MB of "
          f"raw adapters each)")

    from repro.launch.mesh import round_serve_cache_cap
    cap = round_serve_cache_cap(args.prompt_len + args.decode_steps + 1,
                                args.mesh)
    engine = ServeEngine(bundle, base, gen_ws, registry,
                         n_slots=args.n_slots, cache_cap=cap,
                         decode_horizon=args.horizon,
                         quantized_cache=args.quantized_cache,
                         expansion_cache=ExpansionCache(), mesh=mesh)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    reqs = []
    for t in range(args.tasks):
        for _ in range(args.requests_per_task):
            prompt = rng.integers(0, cfg.vocab, args.prompt_len).tolist()
            reqs.append(engine.submit(f"task{t}", prompt,
                                      args.decode_steps + 1))
    engine.run_until_idle()
    dt = time.perf_counter() - t0
    for r in reqs:
        print(f"req {r.req_id} [{r.task_id}]: last tokens "
              f"{r.generated[-4:]}")
    total = sum(len(r.prompt) + len(r.generated) for r in reqs)
    print(f"served {total} tokens across {args.tasks} adapter sets in "
          f"{dt:.2f}s ({total / dt:.1f} tok/s on CPU) — mixed-task decode "
          "batches, expansion cached per bundle (Table 4 regime)")
    mode = "coded bundles" if args.quantized_cache else "expanded adapters"
    print(f"expansion cache ({mode}): {engine.cache.stats()}")
    snap = engine.metrics.snapshot()
    dstep = snap.get("decode_step_s", {})
    print(f"decode hot path: {snap['decode_steps']} decode steps fused into "
          f"{snap['decode_blocks']} device blocks (K<={args.horizon}, one "
          f"host sync each), decode step p50 "
          f"{dstep.get('p50', 0) * 1e3:.2f} ms / p95 "
          f"{dstep.get('p95', 0) * 1e3:.2f} ms, last-step throughput "
          f"{snap['tokens_per_s']:.0f} tok/s")
    print(f"adapter stacking: {snap['adapter_slot_writes']} incremental "
          f"slot writes, {snap['adapter_full_restacks']} full restacks "
          "(always 0 on the fused path)")

    # Hot swap: republish task0 with rescaled betas; the engine picks up the
    # new weights on the very next request — no restart.
    old = registry.load("task0")
    new_state = jax.tree.map(lambda x: x * 5.0 if x.ndim == 2 else x,
                             old.state)
    registry.publish("task0", new_state, gen, adapter={"rank": 4})
    prompt = rng.integers(0, cfg.vocab, args.prompt_len).tolist()
    r = engine.submit("task0", prompt, args.decode_steps + 1)
    engine.run_until_idle()
    print(f"hot-swapped task0 (bundle v{registry.load('task0').version}); "
          f"post-swap tokens {r.generated[-4:]}")


if __name__ == "__main__":
    main()
