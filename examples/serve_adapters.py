"""Batched multi-task adapter serving — the paper's Table-4 motivating
scenario: ONE frozen base model, MANY tasks' MCNC adapters, expanded on the
fly per request batch ("processing multiple tasks and their corresponding
adapters in a batch... MCNC holds an advantage over NOLA due to its faster
throughput").

This driver: builds a base model + N task adapter states (each a tiny
(seed, alpha, beta) bundle), then serves a mixed request batch — prefill +
a few decode steps per task group — timing expansion vs model time, and
compares with NOLA's expansion for the same trainable budget.

    PYTHONPATH=src python examples/serve_adapters.py [--tasks 4]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.core.generator import GeneratorConfig, init_generator
from repro.train.steps import build_bundle, make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=4)
    ap.add_argument("--batch-per-task", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--decode-steps", type=int, default=8)
    args = ap.parse_args()

    arch = get_arch("yi_6b")
    gen = GeneratorConfig(k=5, d=1000, width=32, seed=0)
    bundle = build_bundle(arch, "mcnc", smoke=True, generator=gen,
                          adapter_rank=4)
    cfg = bundle.model_cfg
    base = bundle.init_base(jax.random.PRNGKey(0))
    gen_ws = init_generator(gen)

    # N per-task adapter states (in real use these come from N fine-tunes;
    # here: distinct random alphas). Each is seed + alpha/beta — MBs, not GBs.
    def make_task_state(i):
        st = bundle.init_trainable(jax.random.PRNGKey(100 + i))
        return jax.tree.map(
            lambda x: (x + 0.3 * jax.random.normal(
                jax.random.PRNGKey(200 + i), x.shape).astype(x.dtype))
            if x.ndim == 3 else x, st)

    states = [make_task_state(i) for i in range(args.tasks)]
    n_tp = bundle.plan.trainable_params
    print(f"{args.tasks} task adapters x {n_tp} trainable params each "
          f"(~{n_tp * 4 / 1024:.1f} KiB/task vs "
          f"{bundle.plan.represented_params * 2 / 1e6:.1f} MB of raw "
          f"adapters each)")

    cap = args.prompt_len + args.decode_steps + 1
    prefill = jax.jit(make_prefill_step(bundle, cache_cap=cap))
    decode = jax.jit(make_decode_step(bundle))

    b = args.batch_per_task
    total_tokens = 0
    t0 = time.perf_counter()
    for t, st in enumerate(states):
        prompts = jax.random.randint(jax.random.PRNGKey(300 + t),
                                     (b, args.prompt_len), 0, cfg.vocab)
        logits, cache = prefill(st, base, gen_ws, {"inputs": prompts})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(args.decode_steps):
            pos = jnp.int32(args.prompt_len + i)
            logits, cache = decode(st, base, gen_ws, cache, tok, pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(logits)
        total_tokens += b * (args.prompt_len + args.decode_steps)
        print(f"task {t}: served batch of {b}, "
              f"last tokens {list(map(int, tok))}")
    dt = time.perf_counter() - t0
    print(f"served {total_tokens} tokens across {args.tasks} adapter sets "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s on CPU) — "
          "expansion ran inside every prefill/decode step (unmerged "
          "adapters; Table 4 regime)")


if __name__ == "__main__":
    main()
