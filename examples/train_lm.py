"""End-to-end training driver: MCNC fine-tuning of a transformer LM on the
deterministic synthetic stream with checkpoint/auto-resume.

Presets:
    tiny (default) — ~3M param backbone, runs a few hundred steps on CPU.
    100m           — ~100M param backbone (the assignment's e2e scale; give
                     it real CPU time or a real accelerator).

    PYTHONPATH=src python examples/train_lm.py --steps 300 [--preset 100m]
        [--mode mcnc|lora|nola|pranc] [--resume] [--ckpt-dir ckpts/lm]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.registry import ArchSpec
from repro.core.generator import GeneratorConfig
from repro.data.pipeline import LMStream, LMStreamConfig
from repro.models.lm import ModelConfig
from repro.train.loop import LoopConfig, run_training
from repro.train.steps import build_bundle

PRESETS = {
    "tiny": ModelConfig(name="tiny_lm", n_layers=4, d_model=192, n_heads=6,
                        n_kv_heads=2, head_dim=32, d_ff=512, vocab=2048,
                        attn_chunk=64, remat=False),
    # ~100M params: 12L, d=768, ff=2048, vocab 8192
    "100m": ModelConfig(name="lm_100m", n_layers=12, d_model=768, n_heads=12,
                        n_kv_heads=4, head_dim=64, d_ff=2048, vocab=8192,
                        attn_chunk=128, remat=True),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--mode", default="mcnc",
                    choices=["mcnc", "lora", "nola", "pranc"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    arch = ArchSpec(arch_id=cfg.name, family="dense", kind="lm", config=cfg,
                    smoke_config=cfg, quadratic_attention=True,
                    adapter_rank=8,
                    generator=GeneratorConfig(k=5, d=2000, width=32))
    bundle = build_bundle(arch, args.mode, smoke=True,
                          generator=arch.generator)
    n_params = sum(
        int(x.size) for x in jax.tree.leaves(
            jax.eval_shape(bundle.init_base, jax.random.PRNGKey(0))))
    print(f"preset={args.preset} backbone≈{n_params/1e6:.1f}M params "
          f"mode={args.mode}")
    if bundle.plan is not None:
        print(f"trainable={bundle.plan.trainable_params} "
              f"(rate {bundle.plan.compression_rate:.4%} of adapters)")

    data = LMStream(LMStreamConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                                   global_batch=args.batch, seed=0))
    loop = LoopConfig(steps=args.steps, lr=args.lr,
                      ckpt_dir=args.ckpt_dir, resume=args.resume,
                      log_every=max(args.steps // 20, 1))
    out = run_training(bundle, data.batch, loop,
                       log_fn=lambda r: print(
                           f"step {r['step']:4d} loss {r['loss']:.4f} "
                           f"gnorm {r['grad_norm']:.3f} "
                           f"({r['elapsed_s']}s)"))
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
