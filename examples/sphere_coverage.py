"""Paper Fig. 2 reproduction: traversal of S^2 by a 1-D manifold through
generators with different activations, quantified by exp(-tau * W2^2)
against U(S^{d-1}); plus the S3.1 SWGAN-trained generator (Table 9 setup).

    PYTHONPATH=src python examples/sphere_coverage.py [--train]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.generator import GeneratorConfig, init_generator
from repro.core.manifold import coverage_metric, train_generator_swgan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train", action="store_true",
                    help="also run the SWGAN-trained generator comparison")
    ap.add_argument("--d", type=int, default=3)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    print(f"coverage of S^{args.d - 1} by k=1 generators "
          f"(paper Fig. 2: 1 -> 1024 -> 1024 -> {args.d}):")
    print(f"{'activation':>10s} " + " ".join(f"L={L:<6}" for L in
                                             (1.0, 4.0, 16.0)))
    for act in ("sine", "sigmoid", "relu"):
        row = []
        for L in (1.0, 4.0, 16.0):
            cfg = GeneratorConfig(k=1, d=args.d, width=1024, depth=3,
                                  freq=L, activation=act, seed=0)
            ws = init_generator(cfg)
            cov = float(coverage_metric(cfg, ws, key, l_bound=1.0, n=2048))
            row.append(cov)
        print(f"{act:>10s} " + " ".join(f"{c:.3f}  " for c in row))
    print("(paper: random sine generators at large L already cover well; "
          "ReLU/Sigmoid collapse)")

    if args.train:
        cfg = GeneratorConfig(k=1, d=args.d, width=256, depth=3, freq=4.0,
                              activation="sine", seed=0)
        res = train_generator_swgan(cfg, jax.random.PRNGKey(1), steps=150,
                                    batch=512)
        print(f"SWGAN training: coverage {res.coverage_before:.3f} -> "
              f"{res.coverage_after:.3f} "
              "(paper S3.1: optimization only marginally improves sine)")


if __name__ == "__main__":
    main()
